"""AcceleratedLiNGAM on TPU: a JAX + Pallas causal-discovery framework.

Reproduction and scale-out of "AcceleratedLiNGAM: Learning Causal DAGs at
the speed of GPUs" (Akinwande & Kolter, 2024) — see DESIGN.md.
"""

__version__ = "0.1.0"
