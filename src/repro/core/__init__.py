from .api import (  # noqa: F401
    FitConfig,
    FitResult,
    Partition,
    fit_fn,
    fit_from_stats,
)
from .batched import (  # noqa: F401
    bootstrap_fits,
    fit_many,
    fit_many_from_stats,
    resample_indices,
)
from .bootstrap import BootstrapResult, bootstrap_lingam  # noqa: F401
from .direct_lingam import DirectLiNGAM, fit_direct_lingam  # noqa: F401
from .ordering import (  # noqa: F401
    causal_order,
    causal_order_compact,
    causal_order_staged,
    ordering_scores,
)
from .pruning import estimate_adjacency  # noqa: F401
from .var_lingam import VarLiNGAM, fit_var_lingam  # noqa: F401
