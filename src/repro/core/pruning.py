"""Adjacency estimation given a causal order.

After DirectLiNGAM establishes the order k(.), the connection strengths are
estimated by regressing each variable on its predecessors. The paper leaves
this on CPU (numpy/sklearn, ~4% of runtime); here it is vectorized as a
masked *batched* OLS (one vmapped linear solve per variable) plus an
optional adaptive-lasso refinement (FISTA on the weighted-L1 problem, the
jax-native equivalent of lingam's LassoLarsIC step).

The per-variable solves are row-independent given the (replicated)
covariance, so the mesh execution plan (:mod:`repro.core.sharded`) calls
the row-tile entry points (:func:`ols_rows`, :func:`lasso_rows`) on its
pair-axis tile and ``all_gather``s the rows — bit-identical to the
single-device solve because each row's computation is unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

EPS = 1e-9


def pred_mask(order):
    """(d, d) bool: mask[i, j] = True iff j precedes i in the causal order."""
    d = order.shape[0]
    pos = jnp.zeros((d,), jnp.int32).at[order].set(jnp.arange(d, dtype=jnp.int32))
    return pos[None, :] < pos[:, None]


_pred_mask = pred_mask  # backwards-compatible private alias


def ols_rows(cov, mask_rows, cov_rows):
    """Masked OLS solves for a tile of variables.

    Args:
      cov:       (d, d) covariance of the centered data (replicated).
      mask_rows: (tile, d) predecessor masks for the tile's variables.
      cov_rows:  (tile, d) the same variables' covariance rows.
    Returns:
      (tile, d) coefficient rows. Rows whose mask is all-False (e.g.
      mesh padding rows) solve an identity system and come back zero.
    """

    def solve_one(mask_i, cov_xi):
        mm = mask_i[:, None] & mask_i[None, :]
        a = jnp.where(mm, cov, 0.0) + jnp.diag(jnp.where(mask_i, EPS, 1.0))
        b = jnp.where(mask_i, cov_xi, 0.0)
        return jnp.linalg.solve(a, b)

    return jax.vmap(solve_one)(mask_rows, cov_rows)


def ols_from_cov(cov, order):
    """Masked OLS adjacency from a precomputed (ddof=0) covariance.

    The data-free tail of :func:`ols_adjacency`: given the centered
    covariance — from raw data, or merged incrementally by the streaming
    moment store — the per-variable solves need no further data pass.
    """
    mask = pred_mask(order)  # (d, d)
    return ols_rows(cov, mask, cov)


@functools.partial(jax.jit, static_argnames=())
def ols_adjacency(x, order):
    """Batched masked OLS: B[i, j] = coefficient of x_j in the regression of
    x_i on its causal predecessors. Rows/cols outside the predecessor set are
    pinned via an identity-augmented system so one vmapped solve handles all
    variables with static shapes.
    """
    m, d = x.shape
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    cov = (xc.T @ xc) / m  # (d, d)
    return ols_from_cov(cov, order)


def _soft_threshold(z, t):
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)


def lasso_rows(cov, mask_rows, cov_rows, w_rows, lam, lip, n_steps):
    """FISTA adaptive-lasso solves for a tile of variables.

    Args:
      cov:       (d, d) correlation of the standardized data (replicated).
      mask_rows: (tile, d) predecessor masks.
      cov_rows:  (tile, d) correlation rows of the tile's variables.
      w_rows:    (tile, d) adaptive weights 1/|b_ols|^gamma.
    Returns:
      (tile, d) standardized-unit coefficient rows.
    """
    d = cov.shape[0]

    def fista(mask_i, cov_xi, w_i):
        mm = mask_i[:, None] & mask_i[None, :]
        a = jnp.where(mm, cov, 0.0)
        g = jnp.where(mask_i, cov_xi, 0.0)

        def step(carry, _):
            b, y, t = carry
            grad = a @ y - g
            b_new = _soft_threshold(y - grad / lip, lam * w_i / lip)
            b_new = jnp.where(mask_i, b_new, 0.0)
            t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            y_new = b_new + ((t - 1.0) / t_new) * (b_new - b)
            return (b_new, y_new, t_new), None

        b0 = jnp.zeros((d,), jnp.float32)
        (b, _, _), _ = jax.lax.scan(
            step, (b0, b0, jnp.float32(1.0)), None, length=n_steps
        )
        return b

    return jax.vmap(fista)(mask_rows, cov_rows, w_rows)


@functools.partial(jax.jit, static_argnames=("n_steps",))
def adaptive_lasso_adjacency(x, order, lam=0.01, gamma=1.0, n_steps=400):
    """Adaptive lasso via FISTA, weights w_j = 1/|b_ols_j|^gamma.

    Solved in *standardized* units (correlation matrix) so ``lam`` is
    dimensionless and the quadratic is well conditioned (L <= d); the
    coefficients are rescaled back to raw units at the end. Per variable i
    (vectorized over i):
        min_b 0.5 b^T R b - r_i^T b + lam * sum_j w_j |b_j|
    Predecessors enter through masks so shapes stay static.
    """
    m, d = x.shape
    sd = jnp.maximum(jnp.std(x, axis=0), 1e-12)
    xc = (x - jnp.mean(x, axis=0, keepdims=True)) / sd
    cov = (xc.T @ xc) / m  # correlation
    mask = pred_mask(order)  # (d, d) bool
    # OLS weights in standardized units.
    b_ols_raw = ols_adjacency(x, order)
    b_ols = b_ols_raw * (sd[None, :] / sd[:, None])
    w = 1.0 / jnp.maximum(jnp.abs(b_ols), 1e-3) ** gamma  # (d, d)

    # Lipschitz bound: trace of the correlation matrix = d (cheap, safe).
    lip = jnp.float32(d)

    b_std = lasso_rows(cov, mask, cov, w, lam, lip, n_steps)
    return b_std * (sd[:, None] / sd[None, :])


@functools.partial(jax.jit, static_argnames=("n_steps",))
def adaptive_lasso_from_cov(cov, order, lam=0.01, gamma=1.0, n_steps=400):
    """Adaptive lasso from a precomputed (ddof=0) covariance.

    Same estimator as :func:`adaptive_lasso_adjacency` with the
    correlation and OLS weights derived from ``cov`` instead of a data
    pass (the standardized-unit quadratic is identical in exact
    arithmetic; fp32 agreement is to reduction order). This is the
    streaming path: the rolling moment store hands its merged covariance
    straight to the solver.
    """
    d = cov.shape[0]
    sd = jnp.maximum(jnp.sqrt(jnp.maximum(jnp.diagonal(cov), 0.0)), 1e-12)
    corr = cov / (sd[:, None] * sd[None, :])
    mask = pred_mask(order)
    b_ols = ols_from_cov(cov, order) * (sd[None, :] / sd[:, None])
    w = 1.0 / jnp.maximum(jnp.abs(b_ols), 1e-3) ** gamma
    lip = jnp.float32(d)
    b_std = lasso_rows(corr, mask, corr, w, lam, lip, n_steps)
    return b_std * (sd[:, None] / sd[None, :])


def apply_threshold(b, threshold: float):
    """Zero entries with |B_ij| < threshold (no-op for threshold <= 0)."""
    if threshold > 0.0:
        b = jnp.where(jnp.abs(b) >= threshold, b, 0.0)
    return b


def estimate_adjacency(
    x, order, method: str = "ols", threshold: float = 0.0, **kw
):
    """Adjacency matrix B with B[i, j] = direct effect of x_j on x_i."""
    if method == "ols":
        b = ols_adjacency(x, order)
    elif method == "adaptive_lasso":
        b = adaptive_lasso_adjacency(x, order, **kw)
    else:
        raise ValueError(f"unknown method: {method}")
    return apply_threshold(b, threshold)


def estimate_adjacency_from_cov(
    cov, order, method: str = "ols", threshold: float = 0.0, **kw
):
    """:func:`estimate_adjacency` from precomputed moments (no data pass).

    Every supported pruner reads the data only through its centered
    covariance, so a caller holding sufficient statistics (the streaming
    moment store, ``api.fit_from_stats``) skips the O(m d^2) covariance
    matmul entirely.
    """
    if method == "ols":
        b = ols_from_cov(cov, order)
    elif method == "adaptive_lasso":
        b = adaptive_lasso_from_cov(cov, order, **kw)
    else:
        raise ValueError(f"unknown method: {method}")
    return apply_threshold(b, threshold)
