"""Batched execution engine: many DirectLiNGAM fits as one program.

The paper's accelerated ordering makes a *single* fit fast; its
applications (gene networks, stock graphs) need *many* fits — bootstrap
resamples, ensembles over datasets, scenario sweeps. This module turns
``api.fit_fn`` into a device-parallel engine:

  * :func:`fit_many` — ``vmap(fit_fn)`` over a leading dataset axis:
    (b, m, d) -> batched :class:`~repro.core.api.FitResult`. One compile
    for the whole ensemble.
  * :func:`resample_indices` — bootstrap index matrix generated on-device
    with ``jax.random`` (deterministic in the seed; shared by the vmap
    engine and the host-loop fallback so both fit identical resamples).
  * :func:`bootstrap_fits` — gather + vmapped refit of all resamples in a
    single jitted call: the resample gather, every ordering scan, every
    adjacency solve, and the edge statistics all live in one XLA program.

This module is the **vmap** execution plan of the shared ordering step
(:func:`repro.core.ordering.ordering_step`): it maps the local plan's
reducer over a leading dataset axis — the mesh plan
(``FitConfig.partition``) is the orthogonal scale-out direction and
cannot be nested inside ``vmap`` (both would claim the devices), so
partitioned configs are rejected here with a pointer to ``fit_fn``.

Under ``vmap`` the staged-compaction ordering (``compaction="staged"``)
still works: each batch element gathers along its *own* surviving
columns (batched ``take``), so the engine keeps compaction's ~2x FLOP
cut on top of batching.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs import compile_log
from repro.obs import profile as obs_profile

from .api import FitConfig, FitResult, fit_impl, fit_impl_from_stats


def pow2_bucket(n: int, cap: int) -> int:
    """Next power of two >= n, capped at ``cap`` — the shared
    micro-batch padding policy: serving fit batches, query-engine
    buckets, and RCA sample slabs all round partial batches up to a
    bounded set of program shapes (log2(cap) + 1 of them) instead of
    compiling one program per distinct length."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


def _require_local_plan(config: FitConfig, engine: str) -> None:
    if config.partition is not None:
        raise ValueError(
            f"{engine} vmaps the local execution plan and cannot nest a "
            "mesh partition; drop config.partition, or fit each dataset "
            "through api.fit_fn (the mesh plan) / serve the batch via "
            "CausalDiscoveryEngine, which routes partitioned configs "
            "per-dataset."
        )


@functools.partial(jax.jit, static_argnames=("config",))
def _fit_many_jit(xs, config: FitConfig) -> FitResult:
    _require_local_plan(config, "fit_many")
    compile_log.record("batched.fit_many", shape=xs.shape, config=config)
    return jax.vmap(lambda x: fit_impl(x, config))(xs)


def fit_many(xs, config: FitConfig = FitConfig()) -> FitResult:
    """Fit every dataset in ``xs`` (b, m, d); returns a batched FitResult
    (order: (b, d), adjacency: (b, d, d), resid_var: (b, d))."""
    return obs_profile.call(
        _fit_many_jit, xs, config,
        op="batched.fit_many", shape=xs.shape, config=config,
    )


@functools.partial(jax.jit, static_argnames=("config",))
def _fit_many_from_stats_jit(xs, means, covs, config: FitConfig) -> FitResult:
    _require_local_plan(config, "fit_many_from_stats")
    compile_log.record(
        "batched.fit_many_from_stats", shape=xs.shape, config=config
    )
    return jax.vmap(
        lambda x, mu, cv: fit_impl_from_stats(x, mu, cv, config)
    )(xs, means, covs)


def fit_many_from_stats(
    xs, means, covs, config: FitConfig = FitConfig()
) -> FitResult:
    """Batched :func:`~repro.core.api.fit_from_stats`: datasets (b, m, d)
    with their precomputed moments — means (b, d), ddof=0 covariances
    (b, d, d) — fit as one vmapped program. The serving engine routes
    due stream-session refits here so a burst of rolling windows costs
    one device-parallel dispatch instead of b sequential fits."""
    return obs_profile.call(
        _fit_many_from_stats_jit, xs, means, covs, config,
        op="batched.fit_many_from_stats", shape=xs.shape, config=config,
    )


def warmup_fit_many(shape, config: FitConfig = FitConfig(), *, batch: int = 1):
    """Prime the vmap plan for datasets of ``shape`` before traffic
    arrives: one zeros-fit traces + compiles ``fit_many`` (and, through
    dispatch at trace time, freezes the kernel block plans currently in
    the tuning table). The serving engine's ``warmup`` calls this after
    resolving/measuring plans so first requests pay neither search nor
    compile."""
    m, d = shape
    xs = jnp.zeros((batch, m, d), jnp.float32)
    jax.block_until_ready(fit_many(xs, config).order)


@functools.partial(jax.jit, static_argnames=("n_sampling", "m"))
def resample_indices(seed, n_sampling: int, m: int):
    """(n_sampling, m) int32 bootstrap row indices, drawn on-device."""
    key = jax.random.key(seed)
    return jax.random.randint(key, (n_sampling, m), 0, m, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("config",))
def _bootstrap_fits_jit(x, indices, config: FitConfig) -> FitResult:
    _require_local_plan(config, "bootstrap_fits")
    compile_log.record(
        "batched.bootstrap_fits", shape=indices.shape, config=config
    )
    xs = jnp.take(x.astype(jnp.float32), indices, axis=0)  # (b, m, d)
    return jax.vmap(lambda xb: fit_impl(xb, config))(xs)


def bootstrap_fits(x, indices, config: FitConfig = FitConfig()) -> FitResult:
    """All bootstrap refits as one compiled program.

    Args:
      x:       (m, d) data.
      indices: (n_sampling, m) int32 resample rows (see
               :func:`resample_indices`).
    Returns:
      The batched FitResult over resamples (adjacency: (n_sampling, d, d)).
      Edge statistics are a cheap host-side reduction over it
      (``bootstrap._summarize``), kept out of this program so threshold
      sweeps reuse the compile cache.
    """
    return obs_profile.call(
        _bootstrap_fits_jit, x, indices, config,
        op="batched.bootstrap_fits", shape=indices.shape, config=config,
    )


@functools.partial(jax.jit, static_argnames=("config", "post"))
def _bootstrap_fits_with_jit(
    x, indices, config: FitConfig, post
) -> "tuple[FitResult, object]":
    _require_local_plan(config, "bootstrap_fits_with")
    xs = jnp.take(x.astype(jnp.float32), indices, axis=0)  # (b, m, d)

    def one(xb):
        r = fit_impl(xb, config)
        return r, post(r)

    return jax.vmap(one)(xs)


def bootstrap_fits_with(
    x, indices, config: FitConfig, post
) -> "tuple[FitResult, object]":
    """:func:`bootstrap_fits` plus a per-resample in-trace reduction.

    ``post`` (static — pass a module-level function, not a lambda, or
    every call re-traces) maps each resample's :class:`FitResult` to an
    arbitrary pytree *inside* the same compiled program, so derived
    statistics — the query subsystem's total-effect matrices, for one
    (:func:`repro.infer.effects.bootstrap_effects`) — cost no extra
    dispatch or host round-trip. Returns ``(batched FitResult, batched
    post pytree)``.
    """
    return obs_profile.call(
        _bootstrap_fits_with_jit, x, indices, config, post,
        op="batched.bootstrap_fits_with", shape=indices.shape, config=config,
    )
