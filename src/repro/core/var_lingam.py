"""VarLiNGAM (Hyvarinen et al., 2010) — autoregressive LiNGAM extension.

    x(t) = sum_{tau=0..k} theta_tau x(t - tau) + e(t)

Procedure (paper §3.2):
  1. Fit a VAR(k) model by least squares -> coefficient matrices M_tau.
  2. Run DirectLiNGAM on the VAR residuals -> instantaneous matrix B0
     (this is where ~96% of the runtime goes, hence the same kernel).
  3. Transform the lagged coefficients: theta_tau = (I - B0) @ M_tau.

The VAR estimation is a single batched lstsq on TPU (the paper uses
statsmodels on CPU for this step). Step 2 routes through the functional
core (``api.fit_fn``) — the facade only orchestrates the VAR regression
and the coefficient transform around the pure fit. Setting ``partition``
runs that residual ordering on the mesh plan (``shard_map`` over the
configured device mesh) — with ``Partition(gather_finish=False)`` the
whole fit stays sharded end to end, which is how VarLiNGAM scales past
one device's memory on wide panels (the Jiao et al. scaling regime).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from . import api


def estimate_var(x, lags: int = 1):
    """Least-squares VAR(k): returns (coefs [k, d, d], intercept [d],
    residuals [m - k, d])."""
    x = jnp.asarray(x, dtype=jnp.float32)
    m, d = x.shape
    y = x[lags:]  # (m - k, d)
    z = jnp.concatenate(
        [x[lags - tau - 1 : m - tau - 1] for tau in range(lags)], axis=1
    )  # (m - k, k * d), column block tau holds x(t - tau - 1)
    z1 = jnp.concatenate([jnp.ones((y.shape[0], 1), x.dtype), z], axis=1)
    coef, *_ = jnp.linalg.lstsq(z1, y)
    intercept = coef[0]
    mats = coef[1:].T.reshape(d, lags, d).transpose(1, 0, 2)  # [k, d, d]
    resid = y - z1 @ coef
    return mats, intercept, resid


@dataclasses.dataclass
class VarLiNGAM:
    lags: int = 1
    backend: Optional[str] = None
    interpret: Optional[bool] = None
    prune_method: str = "ols"
    prune_threshold: float = 0.0
    compaction: str = "none"
    partition: Optional[api.Partition] = None
    tune: str = "cache"

    causal_order_: Optional[np.ndarray] = None
    adjacency_matrices_: Optional[List[np.ndarray]] = None  # [theta_0..k]
    var_coefs_: Optional[np.ndarray] = None
    residuals_: Optional[np.ndarray] = None
    result_: Optional[api.FitResult] = None

    def to_config(self) -> api.FitConfig:
        return api.FitConfig(
            backend=self.backend,
            interpret=self.interpret,
            prune_method=self.prune_method,
            prune_threshold=self.prune_threshold,
            compaction=self.compaction,
            partition=self.partition,
            tune=self.tune,
        )

    def fit(self, x) -> "VarLiNGAM":
        mats, _, resid = estimate_var(x, self.lags)
        result = api.fit_fn(resid, self.to_config())
        b0 = result.adjacency
        eye = jnp.eye(b0.shape[0], dtype=b0.dtype)
        thetas = [np.asarray(b0)] + [
            np.asarray((eye - b0) @ mats[tau]) for tau in range(self.lags)
        ]
        self.result_ = result
        self.causal_order_ = np.asarray(result.order)
        self.adjacency_matrices_ = thetas
        self.var_coefs_ = np.asarray(mats)
        self.residuals_ = np.asarray(resid)
        return self


def fit_var_lingam(x, **kw) -> VarLiNGAM:
    return VarLiNGAM(**kw).fit(x)
