"""Mesh execution plan (shard_map) — the scale-out extension.

The paper parallelizes Algorithm 1 within one GPU. This module is the
**mesh** plan of the shared ordering step
(:func:`repro.core.ordering.ordering_step`): it contains *no* estimator
math of its own — scores, entropies, moment integrands, the residual
update, compaction schedules, and pruning all come from
:mod:`repro.core.ordering`, :mod:`repro.core.measures`,
:mod:`repro.kernels.ops`, and :mod:`repro.core.pruning`. What lives here
is only the :class:`MeshReducer` (how the step's reductions execute on a
device mesh) and the ``shard_map`` plumbing:

  * samples are sharded over the ``data`` (and ``pod``) mesh axes — every
    moment in the algorithm is a mean over samples, so shards reduce with
    a single ``psum`` (this is the DP-style axis; scales with m),
  * the (i, j) pair space is tiled over the ``model`` axis — each device
    computes the moment rows for its i-tile only (the Pallas row-tile
    kernel or its jnp fallback via ``ops.pairwise_moment_sums_rows``;
    TP-style axis; scales with d^2),

giving the hybrid sample x pair decomposition analysed in EXPERIMENTS.md
§Perf. Collectives per ordering step:
    psum(C)            : d^2            fp32 over data(+pod)
    psum(M1,M2 tiles)  : 2 d^2/|model|  fp32 over data(+pod)
    all_gather(M rows) : 2 d^2          fp32 over model
Everything else (scores, argmax, rank-1 residual update) is replicated
O(d^2) arithmetic.

:func:`fit_sharded` compiles the *full* fit — ordering (with in-trace
staged compaction when configured: stage widths stay multiples of the
pair-axis size, every shard gathers the same surviving columns) followed
by adjacency/pruning with the per-variable solves tiled over the pair
axis, and residual diagnostics — as one ``shard_map`` program returning
the same :class:`~repro.core.api.FitResult` pytree as the local plan.
The finish has two modes (``Partition.gather_finish``): the default
reassembles the data per device and reduces the covariance in a fixed
replicated order — bit-identical leaves at the parity cells
``tests/test_mesh_fit.py`` pins, fp32-ulp agreement in general — while
``gather_finish=False`` keeps the finish fully sharded (psum-reduced
covariance, local-row diagnostics) so per-device memory stays
O(m_local * d + d^2) end to end.

Variables are padded to a multiple of the pair-axis size and samples to
a multiple of (sample shards x chunk); padded columns enter with
``active=False`` so they never influence scores or updates, and padded
sample rows are zeroed so they drop out of every moment sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.kernels.ops import _round_up
from repro.obs import compile_log
from repro.obs import profile as obs_profile
from . import measures, ordering, pruning
from .api import FitConfig, FitResult


class MeshReducer:
    """Mesh reduction plan: psum over sample shards, row tiles + all_gather
    over the pair axis. Implements the Reducer interface documented on
    :class:`repro.core.ordering.LocalReducer`; must be constructed inside
    the ``shard_map`` trace (it reads ``axis_index``).
    """

    def __init__(
        self,
        *,
        m: int,
        m_local: int,
        axis_sizes,
        sample_axes=("data",),
        pair_axis: str = "model",
        chunk: int = 512,
        backend: str = None,
        interpret: bool = None,
        fused_standardize: bool = False,
        tune: str = "cache",
    ):
        self.m = m
        self.sample_axes = tuple(sample_axes)
        self.pair_axis = pair_axis
        self.n_pair = int(axis_sizes[pair_axis])
        self.col_multiple = self.n_pair
        self.chunk = chunk
        self.backend = backend
        self.interpret = interpret
        self.fused_standardize = fused_standardize
        # Block-shape dispatch mode for the row-tile moment kernel
        # (repro.kernels.tune); the tuned row-tile sizes under shard_map
        # come from here.
        self.tune = tune

        # Which local rows are real samples: rows are distributed evenly
        # over the sample shards (this shard's block starts at
        # shard_id * m_local); the zero-padded tail lives on the last
        # shard(s).
        shard_id = jnp.int32(0)
        for ax in self.sample_axes:
            shard_id = shard_id * int(axis_sizes[ax]) + jax.lax.axis_index(ax)
        row_ids = shard_id * m_local + jnp.arange(m_local)
        self.valid_rows = (row_ids < m)[:, None]  # (m_local, 1)

    def mean_over_samples(self, v):
        """Global sample mean of local rows (padded rows are zero, so the
        local sums are exact sums over real rows)."""
        return jax.lax.psum(jnp.sum(v, axis=0), self.sample_axes) / self.m

    def gram_mean(self, v):
        return jax.lax.psum(v.T @ v, self.sample_axes) / self.m

    def mask_rows(self, v):
        # Padded sample rows must stay exactly zero *after* centering,
        # so mask them instead of shifting them to -mu.
        return jnp.where(self.valid_rows, v, 0.0)

    def standardize(self, x):
        if not self.fused_standardize:
            return ordering.step_standardize(x, self)
        # §Perf C2: correlation from the raw-X matmul + affine fold
        # C = D (G/m - mu mu^T) D with G = X^T X, D = diag(rstd) —
        # skips one standardized-slab matmul pass per step (padded
        # rows are zeros, so raw second moments are exact). The affine
        # fold is one-pass by construction (that is the trick); the
        # variance itself stays two-pass like the shared path.
        mu = self.mean_over_samples(x)
        xc = self.mask_rows(x - mu[None, :])
        var = jnp.maximum(self.mean_over_samples(xc * xc), ordering.EPS)
        rstd = jax.lax.rsqrt(var)
        x_std = xc * rstd[None, :]
        g = self.gram_mean(x)
        c = (g - mu[:, None] * mu[None, :]) * (rstd[:, None] * rstd[None, :])
        return x_std, c, mu, var

    def moment_rows(self, x_std, c):
        """This device's i-row tile of the pairwise residual moments."""
        tile = x_std.shape[1] // self.n_pair
        row_start = jax.lax.axis_index(self.pair_axis) * tile
        s1, s2 = ops.pairwise_moment_sums_rows(
            x_std, c, row_start, tile,
            chunk=self.chunk, backend=self.backend, interpret=self.interpret,
            tune_mode=self.tune,
        )
        s1 = jax.lax.psum(s1, self.sample_axes) / self.m
        s2 = jax.lax.psum(s2, self.sample_axes) / self.m
        return s1, s2

    def gather_rows(self, rows):
        return jax.lax.all_gather(rows, self.pair_axis, axis=0, tiled=True)

    def col_moments(self, x_std):
        # Padded rows are exactly zero and both integrands vanish at 0,
        # so plain sums + /m are exact (logcosh re-masked for safety
        # against constant-folding differences).
        logcosh, uexp = measures.nonlinear_terms(x_std)
        logcosh = jnp.where(self.valid_rows, logcosh, 0.0)
        cm1 = jax.lax.psum(jnp.sum(logcosh, axis=0), self.sample_axes) / self.m
        cm2 = jax.lax.psum(jnp.sum(uexp, axis=0), self.sample_axes) / self.m
        return cm1, cm2

    def gather_samples(self, x_local):
        """Reassemble the full (m_pad, width) array from sample shards
        (exact: a gather moves bits, it does not reduce)."""
        x_full = x_local
        for ax in reversed(self.sample_axes):  # minor axis first
            x_full = jax.lax.all_gather(x_full, ax, axis=0, tiled=True)
        return x_full


def _order_sharded(x_local, d, config: FitConfig, reducer: MeshReducer):
    """The configured ordering schedule on the mesh plan."""
    if config.compaction == "none":
        return ordering.masked_order_impl(x_local, reducer, d=d)
    if config.compaction == "staged":
        return ordering.compact_order_impl(
            x_local, reducer, d=d,
            frac=config.compaction_frac, min_stage=config.min_stage,
        )
    raise ValueError(f"unknown compaction: {config.compaction}")


def _pair_row_tiles(reducer: MeshReducer, order, d: int):
    """Row-tiling helpers for the pair axis: (mask_rows, rows_of, gather).

    The row dimension is padded so every device owns an equal tile;
    padded rows have all-False masks and solve to exactly zero before
    ``gather`` slices them back off.
    """
    n_pair = reducer.n_pair
    d_rows = _round_up(d, n_pair)
    row_tile = d_rows // n_pair
    row_start = jax.lax.axis_index(reducer.pair_axis) * row_tile

    def rows_of(full):
        padded = jnp.pad(full, ((0, d_rows - d), (0, 0)))
        return jax.lax.dynamic_slice_in_dim(padded, row_start, row_tile, 0)

    def gather(rows):
        return jax.lax.all_gather(
            rows, reducer.pair_axis, axis=0, tiled=True
        )[:d]

    return rows_of(pruning.pred_mask(order)), rows_of, gather


def _finish_sharded(x, order, config: FitConfig, reducer: MeshReducer):
    """Bit-exact finish (``gather_finish=True``): adjacency +
    diagnostics on the reassembled data, row solves tiled over the pair
    axis.

    Mirrors :func:`repro.core.api.finish_fit` computation-for-computation:
    the covariance is reduced replicated (fixed reduction order) and
    each variable's masked OLS solve — row-independent given that
    covariance — runs on the device owning its row tile via the shared
    ``pruning.ols_rows``. The adaptive-lasso refinement runs replicated
    through the shared ``pruning`` entry point instead: its FISTA
    iterations are batched matvecs whose reduction lowering depends on
    the batch size, so a row tile would drift from the local plan by
    ulps over the 400 iterations — and it is part of the ~4% tail
    anyway. (Batched ``linalg.solve`` lowering can also differ by batch
    size at some shapes; the parity tests pin the cells where the OLS
    tiles are exact, and elsewhere the tiles agree to ulps.)
    """
    m, d = x.shape
    mask_rows, rows_of, gather = _pair_row_tiles(reducer, order, d)

    if config.prune_method == "ols":
        xc = x - jnp.mean(x, axis=0, keepdims=True)
        cov = (xc.T @ xc) / m
        b = gather(pruning.ols_rows(cov, mask_rows, rows_of(cov)))
    elif config.prune_method == "adaptive_lasso":
        b = pruning.adaptive_lasso_adjacency(
            x, order, **config.prune_kwargs_dict
        )
    else:
        raise ValueError(f"unknown method: {config.prune_method}")

    b = pruning.apply_threshold(b, config.prune_threshold)
    xc0 = x - jnp.mean(x, axis=0, keepdims=True)
    resid = xc0 - xc0 @ b.T
    resid_var = jnp.mean(resid * resid, axis=0)
    return b, resid_var


def _finish_sharded_scaled(
    x_local, order, config: FitConfig, reducer: MeshReducer, d: int
):
    """Fully sharded finish (``gather_finish=False``): the dataset is
    never reassembled — the covariance/correlation are psum-reduced over
    sample shards, solves run on pair-axis row tiles, and the residual
    diagnostics stay on local rows. Per-device memory is
    O(m_local * d + d^2), the scale regime the ordering already runs in;
    coefficients agree with the gathered finish to fp32 reduction order.
    """
    x = x_local[:, :d]
    mask_rows, rows_of, gather = _pair_row_tiles(reducer, order, d)

    mu = reducer.mean_over_samples(x)
    xc = reducer.mask_rows(x - mu[None, :])
    cov = reducer.gram_mean(xc)

    if config.prune_method == "ols":
        b = gather(pruning.ols_rows(cov, mask_rows, rows_of(cov)))
    elif config.prune_method == "adaptive_lasso":
        kw = config.prune_kwargs_dict
        lam = kw.get("lam", 0.01)
        gamma = kw.get("gamma", 1.0)
        n_steps = kw.get("n_steps", 400)
        var = reducer.mean_over_samples(xc * xc)
        sd = jnp.maximum(jnp.sqrt(var), 1e-12)
        corr = reducer.gram_mean(xc / sd[None, :])
        b_ols = gather(pruning.ols_rows(cov, mask_rows, rows_of(cov)))
        b_ols_std = b_ols * (sd[None, :] / sd[:, None])
        w = 1.0 / jnp.maximum(jnp.abs(b_ols_std), 1e-3) ** gamma
        lip = jnp.float32(d)
        b_std = gather(pruning.lasso_rows(
            corr, mask_rows, rows_of(corr), rows_of(w), lam, lip, n_steps
        ))
        b = b_std * (sd[:, None] / sd[None, :])
    else:
        raise ValueError(f"unknown method: {config.prune_method}")

    b = pruning.apply_threshold(b, config.prune_threshold)
    resid = xc - xc @ b.T  # local rows; padded rows are zero -> zero resid
    resid_var = reducer.mean_over_samples(resid * resid)
    return b, resid_var


@functools.lru_cache(maxsize=None)
def _build_sharded_fit(m: int, d: int, config: FitConfig):
    """Compile-cached sharded full-fit program for one (m, d) shape.

    Returns (jitted_fn, m_pad, d_pad); call with (m_pad, d_pad) data.
    """
    from repro.launch.mesh import mesh_from_spec

    part = config.partition
    mesh = mesh_from_spec(part.mesh)
    axis_sizes = dict(part.mesh)
    n_sample_shards = 1
    for ax in part.sample_axes:
        n_sample_shards *= axis_sizes[ax]
    n_pair = axis_sizes[part.pair_axis]

    m_pad = _round_up(m, n_sample_shards * part.chunk)
    d_pad = _round_up(d, n_pair)
    m_local = m_pad // n_sample_shards

    def full_fit(x_local):
        compile_log.record(
            "sharded.fit", shape=(m, d), config=config,
            mesh="x".join(str(s) for _, s in part.mesh),
        )
        reducer = MeshReducer(
            m=m, m_local=m_local, axis_sizes=axis_sizes,
            sample_axes=part.sample_axes, pair_axis=part.pair_axis,
            chunk=part.chunk, backend=config.backend,
            interpret=config.interpret,
            fused_standardize=part.fused_standardize,
            tune=config.tune,
        )
        order = _order_sharded(x_local, d, config, reducer)
        # The ~4% tail: bit-exact on reassembled data, or fully sharded.
        if part.gather_finish:
            x_full = reducer.gather_samples(x_local)[:m, :d]
            b, resid_var = _finish_sharded(x_full, order, config, reducer)
        else:
            b, resid_var = _finish_sharded_scaled(
                x_local, order, config, reducer, d
            )
        return order, b, resid_var

    fn = shard_map(
        full_fit,
        mesh=mesh,
        in_specs=P(part.sample_axes, None),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(fn), m_pad, d_pad


def fit_sharded(x, config: FitConfig) -> FitResult:
    """The mesh plan of ``api.fit_fn``: pad, shard, run the full fit.

    Called by :func:`repro.core.api.fit_fn` when ``config.partition`` is
    set; returns the same :class:`FitResult` pytree as the local plan
    (bit-identical at the test-pinned parity cells; ulp-level agreement
    in general).
    """
    if config.partition is None:
        raise ValueError("fit_sharded requires config.partition")
    x = jnp.asarray(x, jnp.float32)
    m, d = x.shape
    fn, m_pad, d_pad = _build_sharded_fit(m, d, config)
    x_pad = jnp.pad(x, ((0, m_pad - m), (0, d_pad - d)))
    # Keyed on the *unpadded* (m, d) + config, matching the
    # compile_log.record("sharded.fit", ...) inside the trace body.
    order, b, resid_var = obs_profile.call(
        fn, x_pad, op="sharded.fit", shape=(m, d), config=config,
    )
    return FitResult(order=order, adjacency=b, resid_var=resid_var)


def make_sharded_causal_order(
    mesh,
    m: int,
    d: int,
    *,
    sample_axes=("data",),
    pair_axis="model",
    chunk: int = 512,
    backend: str = None,
    interpret: bool = None,
    fused_standardize: bool = False,
    tune: str = "cache",
):
    """Build a jit-able sharded ordering fn for global data of shape (m, d).

    Ordering-only legacy entry point (the dry-run/roofline machinery
    lowers it); :func:`fit_sharded` is the full-fit product path. Returns
    (fn, m_pad, d_pad): call ``fn(x_padded)`` with x of shape
    (m_pad, d_pad) sharded P(sample_axes, None); returns the causal order
    (d,) replicated.

    ``fused_standardize`` (§Perf C2): fold standardization into the
    raw-X matmul, saving one standardized-slab pass per ordering step
    (see :meth:`MeshReducer.standardize`).
    """
    n_sample_shards = 1
    for ax in sample_axes:
        n_sample_shards *= mesh.shape[ax]
    axis_sizes = {ax: mesh.shape[ax] for ax in (*sample_axes, pair_axis)}

    m_pad = _round_up(m, n_sample_shards * chunk)
    d_pad = _round_up(d, mesh.shape[pair_axis])
    m_local = m_pad // n_sample_shards

    def ordered(x_local):
        reducer = MeshReducer(
            m=m, m_local=m_local, axis_sizes=axis_sizes,
            sample_axes=sample_axes, pair_axis=pair_axis, chunk=chunk,
            backend=backend, interpret=interpret,
            fused_standardize=fused_standardize, tune=tune,
        )
        return ordering.masked_order_impl(x_local, reducer, d=d)

    fn = shard_map(
        ordered,
        mesh=mesh,
        in_specs=P(sample_axes, None),
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(fn), m_pad, d_pad


def sharded_causal_order(x, mesh, **kw):
    """Convenience wrapper: pads, shards, runs, returns (d,) order."""
    m, d = x.shape
    fn, m_pad, d_pad = make_sharded_causal_order(mesh, m, d, **kw)
    x_pad = jnp.pad(jnp.asarray(x, jnp.float32), ((0, m_pad - m), (0, d_pad - d)))
    order = fn(x_pad)
    return order[:d] if d_pad != d else order
