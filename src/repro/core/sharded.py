"""Multi-pod sharded causal ordering (shard_map) — the scale-out extension.

The paper parallelizes Algorithm 1 within one GPU. Here the same pair-
independent structure is mapped onto a TPU pod mesh:

  * samples are sharded over the ``data`` (and ``pod``) mesh axes — every
    moment in the algorithm is a mean over samples, so shards reduce with
    a single ``psum`` (this is the DP-style axis; scales with m),
  * the (i, j) pair space is tiled over the ``model`` axis — each device
    computes the moment rows for its i-tile only (TP-style axis; scales
    with d^2),

giving the hybrid sample x pair decomposition analysed in EXPERIMENTS.md
§Perf. Collectives per ordering step:
    psum(C)            : d^2            fp32 over data(+pod)
    psum(M1,M2 tiles)  : 2 d^2/|model|  fp32 over data(+pod)
    all_gather(M rows) : 2 d^2          fp32 over model
Everything else (scores, argmax, rank-1 residual update) is replicated
O(d^2) arithmetic.

Variables are padded to a multiple of the ``model`` axis size and samples
to a multiple of the sample-shard count; padded columns enter with
``active=False`` so they never influence scores or updates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import measures

EPS = 1e-12
_NEG_INF = jnp.float32(-1e30)


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def _local_row_moment_sums(x_std, row_start, tile, c, chunk=512,
                           backend="blocked", interpret=True):
    """Moment *sums* over local samples for rows [row_start, row_start+tile).

    x_std: (m_local, d) locally standardized-by-global-stats data.
    Returns (S1, S2): (tile, d) partial sums (caller psums and divides).
    ``blocked`` scans over sample chunks (pure jnp); ``pallas`` runs the
    paper's kernel on the local slab (row-tile variant) — the kernel
    composed with shard_map is the full multi-pod configuration.
    """
    m_local, d = x_std.shape
    if backend == "pallas":
        from repro.kernels.pairwise_stats import pairwise_moment_sums_rows

        xt_all = x_std.T  # (d, m_local); caller guarantees padding
        xt_rows = jax.lax.dynamic_slice_in_dim(xt_all, row_start, tile, 0)
        c_rows = jax.lax.dynamic_slice_in_dim(c, row_start, tile, 0)
        bi = 8 if tile % 8 == 0 else 1
        bj = 128 if d % 128 == 0 else (8 if d % 8 == 0 else 1)
        bm = chunk if m_local % chunk == 0 else m_local
        return pairwise_moment_sums_rows(
            xt_rows, xt_all, c_rows, m_total=m_local,
            bi=bi, bj=bj, bm=bm, interpret=interpret,
        )
    xt = x_std.T  # (d, m_local)
    c_rows = jax.lax.dynamic_slice_in_dim(c, row_start, tile, 0)  # (tile, d)
    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c_rows * c_rows, EPS))

    m_pad = _round_up(m_local, chunk)
    xt = jnp.pad(xt, ((0, 0), (0, m_pad - m_local)))
    n_chunks = m_pad // chunk
    # Mask the padded tail inside the nonlinearities.
    base_valid = jnp.arange(m_pad) < m_local

    def body(carry, k):
        s1, s2 = carry
        xs = jax.lax.dynamic_slice_in_dim(xt, k * chunk, chunk, 1)  # (d, chunk)
        xi = jax.lax.dynamic_slice_in_dim(xs, row_start, tile, 0)   # (tile, chunk)
        valid = jax.lax.dynamic_slice_in_dim(base_valid, k * chunk, chunk, 0)
        r = xi[:, None, :] - c_rows[:, :, None] * xs[None, :, :]
        u = r * inv_std[:, :, None]
        u = jnp.where(valid[None, None, :], u, 0.0)
        au = jnp.abs(u)
        logcosh = au + jnp.log1p(jnp.exp(-2.0 * au)) - jnp.log(2.0)
        logcosh = jnp.where(valid[None, None, :], logcosh, 0.0)
        s1 = s1 + jnp.sum(logcosh, axis=-1)
        s2 = s2 + jnp.sum(u * jnp.exp(-0.5 * u * u), axis=-1)
        return (s1, s2), None

    init = (
        jnp.zeros((tile, d), jnp.float32),
        jnp.zeros((tile, d), jnp.float32),
    )
    (s1, s2), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return s1, s2


def make_sharded_causal_order(
    mesh,
    m: int,
    d: int,
    *,
    sample_axes=("data",),
    pair_axis="model",
    chunk: int = 512,
    backend: str = "blocked",
    interpret: bool = True,
    fused_standardize: bool = False,
):
    """Build a jit-able sharded ordering fn for global data of shape (m, d).

    Returns (fn, m_pad, d_pad): call ``fn(x_padded)`` with x of shape
    (m_pad, d_pad) sharded P(sample_axes, None); returns the causal order
    (d,) replicated.

    ``fused_standardize`` (§Perf C2): skip materializing the standardized
    slab — correlation comes from the raw-X matmul with the affine fold
    C = D (G/m - mu mu^T) D where G = X^T X and D = diag(rstd), and the
    moment pass standardizes on the fly inside its fused loop. Saves one
    full HBM write+read of the X slab per ordering step. blocked backend
    only (the Pallas path keeps the materialized slab).
    """
    n_sample_shards = 1
    for ax in sample_axes:
        n_sample_shards *= mesh.shape[ax]
    n_pair_shards = mesh.shape[pair_axis]

    m_pad = _round_up(m, n_sample_shards * chunk)
    d_pad = _round_up(d, n_pair_shards)
    tile = d_pad // n_pair_shards

    def local_step(x_local, active):
        """One ordering step on local shard. x_local: (m_local, d_pad)."""
        # --- global standardization (ddof=0) via psum ---
        s1 = jax.lax.psum(jnp.sum(x_local, axis=0), sample_axes)
        s2 = jax.lax.psum(jnp.sum(x_local * x_local, axis=0), sample_axes)
        mu = s1 / m
        var = jnp.maximum(s2 / m - mu * mu, EPS)
        rstd = jax.lax.rsqrt(var)
        m_local = x_local.shape[0]
        # which local rows are real samples: rows are distributed evenly;
        # the pad tail lives on the last shards. Compute per-shard count.
        shard_id = jnp.int32(0)
        for ax in sample_axes:
            shard_id = shard_id * mesh.shape[ax] + jax.lax.axis_index(ax)
        global_start = shard_id * m_local
        row_ids = global_start + jnp.arange(m_local)
        valid = (row_ids < m)[:, None]

        if fused_standardize:
            # §Perf C2: raw-X matmul + affine fold (padded rows are zeros,
            # so raw second moments are exact sums over real rows).
            g = jax.lax.psum(x_local.T @ x_local, sample_axes) / m
            c = (g - mu[:, None] * mu[None, :]) * (
                rstd[:, None] * rstd[None, :]
            )
            # on-the-fly standardized view for the (fused) moment pass
            x_std = jnp.where(
                valid, (x_local - mu[None, :]) * rstd[None, :], 0.0
            )
        else:
            # Padded sample rows must stay exactly zero *after* centering,
            # so mask them instead of shifting them to -mu.
            x_std = jnp.where(
                valid, (x_local - mu[None, :]) * rstd[None, :], 0.0
            )
            # --- correlation via one matmul + psum ---
            c = jax.lax.psum(x_std.T @ x_std, sample_axes) / m

        # --- pair moments for this device's i-tile ---
        row_start = jax.lax.axis_index(pair_axis) * tile
        s1m, s2m = _local_row_moment_sums(
            x_std, row_start, tile, c, chunk,
            backend=backend, interpret=interpret,
        )
        s1m = jax.lax.psum(s1m, sample_axes) / m
        s2m = jax.lax.psum(s2m, sample_axes) / m
        m1 = jax.lax.all_gather(s1m, pair_axis, axis=0, tiled=True)  # (d_pad, d_pad)
        m2 = jax.lax.all_gather(s2m, pair_axis, axis=0, tiled=True)

        # --- scores (replicated O(d^2)) ---
        # Column moments: padded rows are exactly zero, but log cosh(0) = 0
        # anyway, so plain sums + /m are exact.
        a_std = jnp.abs(x_std)
        logcosh_col = a_std + jnp.log1p(jnp.exp(-2.0 * a_std)) - jnp.log(2.0)
        logcosh_col = jnp.where(valid, logcosh_col, 0.0)
        cm1 = jax.lax.psum(jnp.sum(logcosh_col, axis=0), sample_axes) / m
        cm2 = jax.lax.psum(
            jnp.sum(x_std * jnp.exp(-0.5 * x_std * x_std), axis=0), sample_axes
        ) / m
        h_col = measures.entropy_from_moments(cm1, cm2)
        h_res = measures.entropy_from_moments(m1, m2)
        diff = (h_col[None, :] + h_res) - (h_col[:, None] + h_res.T)
        pair_ok = active[:, None] & active[None, :]
        pair_ok &= ~jnp.eye(d_pad, dtype=bool)
        contrib = jnp.where(pair_ok, jnp.minimum(0.0, diff) ** 2, 0.0)
        k_list = jnp.where(active, -jnp.sum(contrib, axis=1), _NEG_INF)
        root = jnp.argmax(k_list)

        # --- residual update on local samples (global moments) ---
        xr = x_local[:, root]
        sxr = jax.lax.psum(jnp.sum(xr), sample_axes) / m
        sxr2 = jax.lax.psum(jnp.sum(xr * xr), sample_axes) / m
        var_r = jnp.maximum(sxr2 - sxr * sxr, EPS)
        sxxr = jax.lax.psum(jnp.sum(x_local * xr[:, None], axis=0), sample_axes) / m
        mu_x = s1 / m
        cov = sxxr - mu_x * sxr
        coef = cov / var_r
        upd = jnp.where(
            active & (jnp.arange(d_pad) != root), coef, 0.0
        )
        x_new = x_local - xr[:, None] * upd[None, :]
        return x_new, active.at[root].set(False), root

    def ordered(x_local):
        active0 = jnp.arange(d_pad) < d

        def body(carry, _):
            xc, act = carry
            xc, act, root = local_step(xc, act)
            return (xc, act), root

        (_, _), order = jax.lax.scan(
            body, (x_local, active0), None, length=d
        )
        return order.astype(jnp.int32)

    fn = shard_map(
        ordered,
        mesh=mesh,
        in_specs=P(sample_axes, None),
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(fn), m_pad, d_pad


def sharded_causal_order(x, mesh, **kw):
    """Convenience wrapper: pads, shards, runs, returns (d,) order."""
    m, d = x.shape
    fn, m_pad, d_pad = make_sharded_causal_order(mesh, m, d, **kw)
    x_pad = jnp.pad(jnp.asarray(x, jnp.float32), ((0, m_pad - m), (0, d_pad - d)))
    order = fn(x_pad)
    return order[:d] if d_pad != d else order
