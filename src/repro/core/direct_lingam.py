"""DirectLiNGAM (Shimizu et al., 2011) — the paper's accelerated target.

Public API:

    model = DirectLiNGAM(backend="pallas").fit(X)
    model.causal_order_   # (d,) — position p holds the variable index
    model.adjacency_      # (d, d) — B[i, j] = direct effect of x_j on x_i

The algorithm is unchanged from the sequential version (identical
identifiability guarantees, as the paper stresses); only the execution is
parallel. ``backend`` picks the pairwise-moment implementation:
"blocked" (vectorized jnp), "pallas" (TPU kernel; interpret=True on CPU),
or "ref" (small-problem oracle).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import ordering, pruning


@dataclasses.dataclass
class DirectLiNGAM:
    backend: str = "blocked"
    interpret: bool = True
    prune_method: str = "ols"
    prune_threshold: float = 0.0
    prune_kwargs: dict = dataclasses.field(default_factory=dict)

    causal_order_: Optional[np.ndarray] = None
    adjacency_: Optional[np.ndarray] = None

    def fit(self, x) -> "DirectLiNGAM":
        x = jnp.asarray(x, dtype=jnp.float32)
        order = ordering.causal_order(
            x, backend=self.backend, interpret=self.interpret
        )
        b = pruning.estimate_adjacency(
            x,
            order,
            method=self.prune_method,
            threshold=self.prune_threshold,
            **self.prune_kwargs,
        )
        self.causal_order_ = np.asarray(order)
        self.adjacency_ = np.asarray(b)
        return self


def fit_direct_lingam(x, **kw) -> DirectLiNGAM:
    return DirectLiNGAM(**kw).fit(x)
