"""DirectLiNGAM (Shimizu et al., 2011) — the paper's accelerated target.

Public API:

    model = DirectLiNGAM(backend="pallas").fit(X)
    model.causal_order_   # (d,) — position p holds the variable index
    model.adjacency_      # (d, d) — B[i, j] = direct effect of x_j on x_i

The algorithm is unchanged from the sequential version (identical
identifiability guarantees, as the paper stresses); only the execution is
parallel. ``backend`` picks the pairwise-moment implementation:
"blocked" (vectorized jnp), "pallas" (TPU kernel; interpret=True on CPU),
or "ref" (small-problem oracle).

This class is a thin stateful facade over the functional core: ``fit``
builds a static :class:`~repro.core.api.FitConfig` and runs the pure
``api.fit_fn`` (one traced program), then materializes the result as
numpy attributes. Batched / bootstrap workloads should use
``repro.core.batched`` (``fit_many``) or
``repro.core.bootstrap.bootstrap_lingam`` directly, which vmap the same
``fit_fn`` instead of looping over facades.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import api


@dataclasses.dataclass
class DirectLiNGAM:
    backend: Optional[str] = None
    interpret: Optional[bool] = None
    prune_method: str = "ols"
    prune_threshold: float = 0.0
    prune_kwargs: dict = dataclasses.field(default_factory=dict)
    compaction: str = "none"
    partition: Optional[api.Partition] = None
    tune: str = "cache"

    causal_order_: Optional[np.ndarray] = None
    adjacency_: Optional[np.ndarray] = None
    resid_var_: Optional[np.ndarray] = None
    result_: Optional[api.FitResult] = None

    def to_config(self) -> api.FitConfig:
        """The static FitConfig equivalent of this facade's settings."""
        return api.FitConfig(
            backend=self.backend,
            interpret=self.interpret,
            prune_method=self.prune_method,
            prune_threshold=self.prune_threshold,
            prune_kwargs=dict(self.prune_kwargs),
            compaction=self.compaction,
            partition=self.partition,
            tune=self.tune,
        )

    def fit(self, x) -> "DirectLiNGAM":
        x = jnp.asarray(x, dtype=jnp.float32)
        result = api.fit_fn(x, self.to_config())
        self.result_ = result
        self.causal_order_ = np.asarray(result.order)
        self.adjacency_ = np.asarray(result.adjacency)
        self.resid_var_ = np.asarray(result.resid_var)
        return self


def fit_direct_lingam(x, **kw) -> DirectLiNGAM:
    return DirectLiNGAM(**kw).fit(x)
