"""Causal ordering (Algorithm 1 of the paper) — vectorized, masked, jit-able.

The paper parallelizes the pair loop of ``search_causal_order`` on GPU. The
TPU-native formulation here goes one step further and expresses the *entire*
ordering loop as a ``lax.scan`` of d identical masked steps over a
static-shape (m, d) buffer:

  step(X, active):
    1. standardize active columns (ddof=0)
    2. C = X_std^T X_std / m                        (one MXU matmul)
    3. (M1, M2) = pairwise residual moments         (Pallas kernel / jnp)
    4. entropies + MI differences -> k_list scores  (O(d^2) postprocess)
    5. root = argmax_{active} k_list                (ties -> lowest index,
                                                     matching np.argmax)
    6. residualize: x_j <- x_j - (cov(x_j, x_root)/var(x_root)) x_root

Inactive columns are masked out of the scores; their data still flows
through the moment computation (static shapes), which preserves the O(d^2 m)
per-step cost of the sequential algorithm while making every step identical
for XLA. Step 6 is the paper's "sequential 4%" — here it is a vectorized
rank-1 update, so the parallel fraction exceeds the paper's 0.96.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import measures

_NEG_INF = jnp.float32(-1e30)
EPS = 1e-12


def ordering_scores(x, active, *, backend="blocked", interpret=True):
    """k_list scores for one ordering step.

    Args:
      x:      (m, d) current (partially residualized) data.
      active: (d,) bool mask of variables still to be ordered.
    Returns:
      (k_list, x_std, c): scores with -inf at inactive entries; the
      standardized data and correlation (reused by the residual update).
    """
    m, d = x.shape
    x_std = ops.standardize(x)
    c = ops.correlation(x_std)
    m1, m2 = ops.pairwise_moments(
        x_std, c, backend=backend, interpret=interpret
    )

    # Column entropies H(x_i).
    cm1, cm2 = measures.nonlinear_moments(x_std, axis=0)
    h_col = measures.entropy_from_moments(cm1, cm2)  # (d,)

    # Residual entropies H(r_{i<-j}/std).
    h_res = measures.entropy_from_moments(m1, m2)  # (d, d), [i, j]

    # diff_mi[i, j] = (H(x_j) + H(r_i<-j)) - (H(x_i) + H(r_j<-i))
    diff = (h_col[None, :] + h_res) - (h_col[:, None] + h_res.T)

    pair_ok = active[:, None] & active[None, :]
    pair_ok &= ~jnp.eye(d, dtype=bool)
    contrib = jnp.where(pair_ok, jnp.minimum(0.0, diff) ** 2, 0.0)
    k_list = -jnp.sum(contrib, axis=1)
    k_list = jnp.where(active, k_list, _NEG_INF)
    return k_list, x_std, c


def _ordering_step(x, active, *, backend, interpret):
    k_list, _, _ = ordering_scores(
        x, active, backend=backend, interpret=interpret
    )
    root = jnp.argmax(k_list)

    # Residualize every other active column on the root column of the
    # *unstandardized* working data (matches the sequential reference).
    xr = x[:, root]
    var_r = jnp.maximum(jnp.var(xr), EPS)
    mean_r = jnp.mean(xr)
    cov = jnp.mean(x * xr[:, None], axis=0) - jnp.mean(x, axis=0) * mean_r
    coef = cov / var_r  # (d,)
    update = jnp.where(active & (jnp.arange(x.shape[1]) != root), coef, 0.0)
    x_new = x - xr[:, None] * update[None, :]

    active_new = active.at[root].set(False)
    return x_new, active_new, root


def _scan_body(backend, interpret):
    """Shared ``lax.scan`` body: one ordering step, emits the chosen root."""

    def body(carry, _):
        xc, act = carry
        xc, act, root = _ordering_step(
            xc, act, backend=backend, interpret=interpret
        )
        return (xc, act), root

    return body


def _causal_order_impl(x, *, backend="blocked", interpret=True, unroll=False):
    """Unjitted trace body of :func:`causal_order` (composable under
    ``jit``/``vmap`` by callers that build larger traced programs)."""
    m, d = x.shape
    x = x.astype(jnp.float32)
    body = _scan_body(backend, interpret)
    init = (x, jnp.ones((d,), dtype=bool))
    if unroll:
        order = []
        carry = init
        for _ in range(d):
            carry, root = body(carry, None)
            order.append(root)
        return jnp.stack(order).astype(jnp.int32)
    (_, _), order = jax.lax.scan(body, init, None, length=d)
    return order.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("backend", "interpret", "unroll")
)
def causal_order(x, *, backend="blocked", interpret=True, unroll=False):
    """Full causal ordering of all d variables.

    Returns ``order`` (d,) int32 — order[p] is the variable at causal
    position p (order[0] = most exogenous).
    """
    return _causal_order_impl(
        x, backend=backend, interpret=interpret, unroll=unroll
    )


def _stage_schedule(d: int, frac: float = 0.25, min_stage: int = 8):
    """Static compaction schedule: [(width, n_steps), ...], sum n = d.

    Each stage runs ``n_steps`` ordering steps at physical width ``width``
    and then gathers the surviving columns into a ``width - n_steps``
    buffer. Smaller ``frac`` compacts more aggressively: total pair work
    approaches the sequential algorithm's d^3/3 instead of the masked
    scan's d^3 (frac=0.25 => ~0.43 d^3, a ~2.3x FLOP cut).
    """
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"compaction frac must be in (0, 1], got {frac}")
    if min_stage < 1:
        raise ValueError(f"min_stage must be >= 1, got {min_stage}")
    sched = []
    d_cur = d
    while d_cur > min_stage:
        n = max(1, int(round(d_cur * frac)))
        sched.append((d_cur, n))
        d_cur -= n
    if d_cur:
        sched.append((d_cur, d_cur))
    return tuple(sched)


def _causal_order_compact_impl(
    x, *, backend="blocked", interpret=True, frac=0.25, min_stage=8
):
    """In-trace staged compaction: one traced program, static stage shapes.

    Unlike :func:`causal_order_staged` (host-driven, one re-jit per
    stage), the whole schedule here is unrolled inside a single trace —
    every stage has a static width, so the function compiles exactly once
    and composes with ``vmap`` (the batched bootstrap engine relies on
    this: each batch element compacts along its *own* surviving columns
    via a batched gather). Active-column arithmetic is identical to the
    full masked scan — inactive columns never influence active ones — so
    the returned order matches :func:`causal_order` exactly.
    """
    d = x.shape[1]
    x = x.astype(jnp.float32)
    labels = jnp.arange(d, dtype=jnp.int32)  # current column -> original
    parts = []
    body = _scan_body(backend, interpret)
    for width, n_steps in _stage_schedule(d, frac, min_stage):
        active = jnp.ones((width,), dtype=bool)
        (x, active), roots = jax.lax.scan(
            body, (x, active), None, length=n_steps
        )
        parts.append(labels[roots])
        keep = width - n_steps
        if keep:
            # Surviving column indices in ascending order (stable under
            # vmap: distinct keys, inactive pushed past the end).
            idx = jnp.argsort(jnp.where(active, jnp.arange(width), width))
            idx = idx[:keep]
            x = jnp.take(x, idx, axis=1)
            labels = labels[idx]
    return jnp.concatenate(parts).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("backend", "interpret", "frac", "min_stage"),
)
def causal_order_compact(
    x, *, backend="blocked", interpret=True, frac=0.25, min_stage=8
):
    """Single-compile staged-compaction ordering (see impl docstring)."""
    return _causal_order_compact_impl(
        x, backend=backend, interpret=interpret, frac=frac,
        min_stage=min_stage,
    )


@functools.partial(
    jax.jit, static_argnames=("n_steps", "backend", "interpret")
)
def _partial_order(x, active, n_steps, *, backend, interpret):
    """Run ``n_steps`` ordering steps; return (roots, x, active)."""
    (x, active), roots = jax.lax.scan(
        _scan_body(backend, interpret), (x, active), None, length=n_steps
    )
    return roots.astype(jnp.int32), x, active


def causal_order_staged(
    x, *, backend="blocked", interpret=True, min_stage=32
):
    """Causal ordering with active-set compaction (§Perf optimization).

    The masked scan in :func:`causal_order` pays the full d^2*m pair cost
    at every one of its d steps even though only the active set matters —
    total ~ m*d^3. This variant halves the *physical* problem every d/2
    steps by gathering the still-active columns into a smaller buffer
    (host-driven re-jit per stage, exact same algorithm => identical
    order), cutting total pair work to ~ m*d^3 * 4/7 (1.75x fewer FLOPs).
    The sequential CPU implementation gets this for free (its U set
    shrinks); this recovers it for the fixed-shape TPU formulation.
    """
    import numpy as np

    x = jnp.asarray(x, jnp.float32)
    d = x.shape[1]
    remaining = np.arange(d)
    order = []
    active = jnp.ones((d,), dtype=bool)
    while len(remaining) > min_stage:
        d_cur = int(x.shape[1])
        n_steps = d_cur - d_cur // 2
        roots, x, active = _partial_order(
            x, active, n_steps, backend=backend, interpret=interpret
        )
        roots = np.asarray(roots)
        order.extend(remaining[roots].tolist())
        keep = np.asarray(~np.isin(np.arange(d_cur), roots)).nonzero()[0]
        x = x[:, keep]
        remaining = remaining[keep]
        active = jnp.ones((len(keep),), dtype=bool)
    if len(remaining):
        tail = causal_order(x, backend=backend, interpret=interpret)
        order.extend(remaining[np.asarray(tail)].tolist())
    return jnp.asarray(order, dtype=jnp.int32)
