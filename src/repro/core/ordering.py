"""Causal ordering (Algorithm 1 of the paper) — vectorized, masked, jit-able.

The paper parallelizes the pair loop of ``search_causal_order`` on GPU. The
TPU-native formulation here goes one step further and expresses the *entire*
ordering loop as a ``lax.scan`` of d identical masked steps over a
static-shape (m, d) buffer:

  step(X, active):
    1. standardize active columns (ddof=0)
    2. C = X_std^T X_std / m                        (one MXU matmul)
    3. (M1, M2) = pairwise residual moments         (Pallas kernel / jnp)
    4. entropies + MI differences -> k_list scores  (O(d^2) postprocess)
    5. root = argmax_{active} k_list                (ties -> lowest index,
                                                     matching np.argmax)
    6. residualize: x_j <- x_j - (cov(x_j, x_root)/var(x_root)) x_root

Inactive columns are masked out of the scores; their data still flows
through the moment computation (static shapes), which preserves the O(d^2 m)
per-step cost of the sequential algorithm while making every step identical
for XLA. Step 6 is the paper's "sequential 4%" — here it is a vectorized
rank-1 update, so the parallel fraction exceeds the paper's 0.96.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import measures

_NEG_INF = jnp.float32(-1e30)
EPS = 1e-12


def ordering_scores(x, active, *, backend="blocked", interpret=True):
    """k_list scores for one ordering step.

    Args:
      x:      (m, d) current (partially residualized) data.
      active: (d,) bool mask of variables still to be ordered.
    Returns:
      (k_list, x_std, c): scores with -inf at inactive entries; the
      standardized data and correlation (reused by the residual update).
    """
    m, d = x.shape
    x_std = ops.standardize(x)
    c = ops.correlation(x_std)
    m1, m2 = ops.pairwise_moments(
        x_std, c, backend=backend, interpret=interpret
    )

    # Column entropies H(x_i).
    cm1, cm2 = measures.nonlinear_moments(x_std, axis=0)
    h_col = measures.entropy_from_moments(cm1, cm2)  # (d,)

    # Residual entropies H(r_{i<-j}/std).
    h_res = measures.entropy_from_moments(m1, m2)  # (d, d), [i, j]

    # diff_mi[i, j] = (H(x_j) + H(r_i<-j)) - (H(x_i) + H(r_j<-i))
    diff = (h_col[None, :] + h_res) - (h_col[:, None] + h_res.T)

    pair_ok = active[:, None] & active[None, :]
    pair_ok &= ~jnp.eye(d, dtype=bool)
    contrib = jnp.where(pair_ok, jnp.minimum(0.0, diff) ** 2, 0.0)
    k_list = -jnp.sum(contrib, axis=1)
    k_list = jnp.where(active, k_list, _NEG_INF)
    return k_list, x_std, c


def _ordering_step(x, active, *, backend, interpret):
    k_list, _, _ = ordering_scores(
        x, active, backend=backend, interpret=interpret
    )
    root = jnp.argmax(k_list)

    # Residualize every other active column on the root column of the
    # *unstandardized* working data (matches the sequential reference).
    xr = x[:, root]
    var_r = jnp.maximum(jnp.var(xr), EPS)
    mean_r = jnp.mean(xr)
    cov = jnp.mean(x * xr[:, None], axis=0) - jnp.mean(x, axis=0) * mean_r
    coef = cov / var_r  # (d,)
    update = jnp.where(active & (jnp.arange(x.shape[1]) != root), coef, 0.0)
    x_new = x - xr[:, None] * update[None, :]

    active_new = active.at[root].set(False)
    return x_new, active_new, root


@functools.partial(
    jax.jit, static_argnames=("backend", "interpret", "unroll")
)
def causal_order(x, *, backend="blocked", interpret=True, unroll=False):
    """Full causal ordering of all d variables.

    Returns ``order`` (d,) int32 — order[p] is the variable at causal
    position p (order[0] = most exogenous).
    """
    m, d = x.shape
    x = x.astype(jnp.float32)

    def body(carry, _):
        xc, act = carry
        xc, act, root = _ordering_step(
            xc, act, backend=backend, interpret=interpret
        )
        return (xc, act), root

    init = (x, jnp.ones((d,), dtype=bool))
    if unroll:
        order = []
        carry = init
        for _ in range(d):
            carry, root = body(carry, None)
            order.append(root)
        return jnp.stack(order).astype(jnp.int32)
    (_, _), order = jax.lax.scan(body, init, None, length=d)
    return order.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_steps", "backend", "interpret")
)
def _partial_order(x, active, n_steps, *, backend, interpret):
    """Run ``n_steps`` ordering steps; return (roots, x, active)."""

    def body(carry, _):
        xc, act = carry
        xc, act, root = _ordering_step(
            xc, act, backend=backend, interpret=interpret
        )
        return (xc, act), root

    (x, active), roots = jax.lax.scan(
        body, (x, active), None, length=n_steps
    )
    return roots.astype(jnp.int32), x, active


def causal_order_staged(
    x, *, backend="blocked", interpret=True, min_stage=32
):
    """Causal ordering with active-set compaction (§Perf optimization).

    The masked scan in :func:`causal_order` pays the full d^2*m pair cost
    at every one of its d steps even though only the active set matters —
    total ~ m*d^3. This variant halves the *physical* problem every d/2
    steps by gathering the still-active columns into a smaller buffer
    (host-driven re-jit per stage, exact same algorithm => identical
    order), cutting total pair work to ~ m*d^3 * 4/7 (1.75x fewer FLOPs).
    The sequential CPU implementation gets this for free (its U set
    shrinks); this recovers it for the fixed-shape TPU formulation.
    """
    import numpy as np

    x = jnp.asarray(x, jnp.float32)
    d = x.shape[1]
    remaining = np.arange(d)
    order = []
    active = jnp.ones((d,), dtype=bool)
    while len(remaining) > min_stage:
        d_cur = int(x.shape[1])
        n_steps = d_cur - d_cur // 2
        roots, x, active = _partial_order(
            x, active, n_steps, backend=backend, interpret=interpret
        )
        roots = np.asarray(roots)
        order.extend(remaining[roots].tolist())
        keep = np.asarray(~np.isin(np.arange(d_cur), roots)).nonzero()[0]
        x = x[:, keep]
        remaining = remaining[keep]
        active = jnp.ones((len(keep),), dtype=bool)
    if len(remaining):
        tail = causal_order(x, backend=backend, interpret=interpret)
        order.extend(remaining[np.asarray(tail)].tolist())
    return jnp.asarray(order, dtype=jnp.int32)
