"""Causal ordering (Algorithm 1 of the paper) — one step, three plans.

The paper parallelizes the pair loop of ``search_causal_order`` on GPU.
Here the *entire* ordering loop is a ``lax.scan`` of d identical masked
steps over a static-shape (m, d) buffer:

  step(X, active):
    1. standardize active columns (ddof=0)
    2. C = X_std^T X_std / m                        (one MXU matmul)
    3. (M1, M2) = pairwise residual moments         (Pallas kernel / jnp)
    4. entropies + MI differences -> k_list scores  (O(d^2) postprocess)
    5. root = argmax_{active} k_list                (ties -> lowest index,
                                                     matching np.argmax)
    6. residualize: x_j <- x_j - (cov(x_j, x_root)/var(x_root)) x_root

There is exactly **one** implementation of this step
(:func:`ordering_step`); what varies between execution plans is only how
the sample/pair reductions are carried out, abstracted behind a small
``Reducer`` interface:

  * :class:`LocalReducer` — plain ``jnp`` reductions on one device. This
    is both the single-device plan and the **vmap** plan: the batched
    engine (:mod:`repro.core.batched`) maps the very same step over a
    leading dataset axis.
  * ``MeshReducer`` (:mod:`repro.core.sharded`) — the **mesh** plan:
    samples sharded over data axes (``psum`` reductions), the (i, j)
    pair space tiled over a model axis (row-tile moments +
    ``all_gather``), run under ``shard_map``.

Inactive columns are masked out of the scores; their data still flows
through the moment computation (static shapes), which preserves the
O(d^2 m) per-step cost of the sequential algorithm while making every
step identical for XLA. Step 6 is the paper's "sequential 4%" — here it
is a vectorized rank-1 update, so the parallel fraction exceeds the
paper's 0.96.

Both scan drivers (:func:`masked_order_impl`, the full masked scan, and
:func:`compact_order_impl`, in-trace staged active-set compaction) take
any reducer, so staged compaction also runs under ``shard_map`` — stage
widths are static and padded to the reducer's ``col_multiple`` (the pair
axis size for the mesh plan) with surviving columns gathered per shard.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import _round_up
from . import measures

_NEG_INF = jnp.float32(-1e30)
EPS = 1e-12


class LocalReducer:
    """Single-device reduction plan (also used, vmapped, by the batched
    engine).

    The Reducer interface every plan implements:

      * ``mean_over_samples(v) -> v.mean(axis=0)`` — the global sample
        mean (a ``psum`` of local sums on a mesh).
      * ``gram_mean(v) -> v^T v / m`` — the global Gram-matrix mean (one
        matmul here; matmul + ``psum`` on a mesh).
      * ``mask_rows(v)`` — zero rows that are sample padding (identity
        here; mesh shards carry a zero-padded tail).
      * ``moment_rows(x_std, c) -> (m1_rows, m2_rows)`` — pairwise
        residual moment *means* for this plan's row tile of the (i, j)
        pair space (the whole of it here; one model-axis tile on a mesh).
      * ``gather_rows(rows) -> (d, d)`` — assemble full moment matrices
        from the row tiles (identity here; ``all_gather`` on a mesh).
      * ``col_moments(x_std) -> (cm1, cm2)`` — per-column nonlinear
        moments for the H(x_i) entropies.
      * ``standardize(x) -> (x_std, c, mu, var)`` — delegates to the
        shared :func:`step_standardize` (a plan may override it to fuse
        the correlation into the raw-X matmul, cf.
        ``fused_standardize``).
      * ``col_multiple`` — physical column widths must be multiples of
        this (1 here; the pair-axis size on a mesh), honoured by the
        staged-compaction driver when it shrinks the buffer.
    """

    col_multiple = 1

    def __init__(
        self,
        backend: str = None,
        interpret: bool = None,
        moment_chunk=None,
        tune: str = "cache",
    ):
        self.backend = backend
        self.interpret = interpret
        # When set, pairwise moments accumulate over (moment_chunk, d)
        # sample slabs (ops.pairwise_moments_chunked) so the per-step
        # residual intermediate is O(chunk * d^2) regardless of m — the
        # streaming plan's rolling-window refits run with chunk-bounded
        # memory. None keeps the classic whole-slab backends.
        self.moment_chunk = moment_chunk
        # Dispatch mode for the block-shape/variant decisions
        # (repro.kernels.tune): "off" | "cache" | "auto".
        self.tune = tune

    def mean_over_samples(self, v):
        return jnp.mean(v, axis=0)

    def gram_mean(self, v):
        return (v.T @ v) / v.shape[0]

    def mask_rows(self, v):
        return v

    def standardize(self, x):
        return step_standardize(x, self)

    def moment_rows(self, x_std, c):
        if self.moment_chunk:
            return ops.pairwise_moments_chunked(
                x_std, c, chunk=self.moment_chunk,
                backend=self.backend, interpret=self.interpret,
                tune_mode=self.tune,
            )
        return ops.pairwise_moments(
            x_std, c, backend=self.backend, interpret=self.interpret,
            tune_mode=self.tune,
        )

    def gather_rows(self, rows):
        return rows

    def col_moments(self, x_std):
        return measures.nonlinear_moments(x_std, axis=0)


def step_standardize(x, reducer):
    """Shared ddof=0 standardization + correlation of the working data.

    Two-pass variance (E[(x - mu)^2], one extra reduction round per step
    on a mesh): the one-pass E[x^2] - mu^2 form catastrophically cancels
    in fp32 when column means dwarf the stds (raw prices, sensor
    offsets), which would corrupt the ordering on un-centered data.
    Padded sample rows (mesh) are re-zeroed *after* centering so they
    stay out of every downstream moment. Returns (x_std, c, mu, var) —
    the residual update reuses mu and var instead of re-reducing.
    """
    mu = reducer.mean_over_samples(x)
    xc = reducer.mask_rows(x - mu[None, :])
    var = jnp.maximum(reducer.mean_over_samples(xc * xc), EPS)
    rstd = jax.lax.rsqrt(var)
    x_std = xc * rstd[None, :]
    c = reducer.gram_mean(x_std)
    return x_std, c, mu, var


def step_scores(cm1, cm2, m1, m2, active):
    """k_list scores from the column / pairwise nonlinear moments.

    The single definition of the DirectLiNGAM score formula — every plan
    (local, vmap, mesh) feeds its reduced moments through this.
    Returns scores with -inf at inactive entries.
    """
    h_col = measures.entropy_from_moments(cm1, cm2)  # (d,)
    h_res = measures.entropy_from_moments(m1, m2)  # (d, d), [i, j]

    # diff_mi[i, j] = (H(x_j) + H(r_i<-j)) - (H(x_i) + H(r_j<-i))
    diff = (h_col[None, :] + h_res) - (h_col[:, None] + h_res.T)

    pair_ok = active[:, None] & active[None, :]
    pair_ok &= ~jnp.eye(active.shape[0], dtype=bool)
    contrib = jnp.where(pair_ok, jnp.minimum(0.0, diff) ** 2, 0.0)
    k_list = -jnp.sum(contrib, axis=1)
    return jnp.where(active, k_list, _NEG_INF)


def ordering_scores(x, active, *, backend=None, interpret=None):
    """k_list scores for one ordering step (local plan).

    Args:
      x:      (m, d) current (partially residualized) data.
      active: (d,) bool mask of variables still to be ordered.
    Returns:
      (k_list, x_std, c): scores with -inf at inactive entries; the
      standardized data and correlation (reused by the residual update).
    """
    reducer = LocalReducer(backend=backend, interpret=interpret)
    x_std, c, _, _ = reducer.standardize(x)
    m1, m2 = reducer.moment_rows(x_std, c)
    cm1, cm2 = reducer.col_moments(x_std)
    return step_scores(cm1, cm2, m1, m2, active), x_std, c


def ordering_step(x, active, reducer):
    """One masked ordering step — the shared implementation.

    Args:
      x:       (m_plan, width) working data (the plan's local sample
               rows; full columns).
      active:  (width,) bool mask of variables still to be ordered.
      reducer: the plan's Reducer (see :class:`LocalReducer`).
    Returns:
      (x_new, active_new, root): residualized data, updated mask, and
      the physical column index chosen this step.
    """
    x_std, c, mu, var = reducer.standardize(x)
    rows1, rows2 = reducer.moment_rows(x_std, c)
    m1 = reducer.gather_rows(rows1)
    m2 = reducer.gather_rows(rows2)
    cm1, cm2 = reducer.col_moments(x_std)
    k_list = step_scores(cm1, cm2, m1, m2, active)
    root = jnp.argmax(k_list)

    # Residualize every other active column on the root column of the
    # *unstandardized* working data (matches the sequential reference).
    # mu/var come from standardize — no extra sample reduction (on a
    # mesh: no extra psum round) for the root's moments. The covariance
    # is two-pass (centered product) for the same fp32-cancellation
    # reason as step_standardize; pad rows are masked after centering.
    xr = x[:, root]
    mean_r = mu[root]
    var_r = var[root]
    cov = reducer.mean_over_samples(
        reducer.mask_rows((x - mu[None, :]) * (xr - mean_r)[:, None])
    )
    coef = cov / var_r  # (width,)
    update = jnp.where(active & (jnp.arange(x.shape[1]) != root), coef, 0.0)
    x_new = x - xr[:, None] * update[None, :]

    return x_new, active.at[root].set(False), root


def _scan_body(reducer):
    """Shared ``lax.scan`` body: one ordering step, emits the chosen root."""

    def body(carry, _):
        xc, act = carry
        xc, act, root = ordering_step(xc, act, reducer)
        return (xc, act), root

    return body


def masked_order_impl(x, reducer, *, d=None, unroll=False):
    """Full masked scan: d identical steps at constant physical width.

    ``d`` is the number of real variables; columns at index >= d (mesh
    padding) start inactive and are never selected. Composable under
    ``jit`` / ``vmap`` / ``shard_map`` by callers building larger traced
    programs.
    """
    width = x.shape[1]
    if d is None:
        d = width
    x = x.astype(jnp.float32)
    body = _scan_body(reducer)
    init = (x, jnp.arange(width) < d)
    if unroll:
        order = []
        carry = init
        for _ in range(d):
            carry, root = body(carry, None)
            order.append(root)
        return jnp.stack(order).astype(jnp.int32)
    (_, _), order = jax.lax.scan(body, init, None, length=d)
    return order.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("backend", "interpret", "unroll")
)
def causal_order(x, *, backend=None, interpret=None, unroll=False):
    """Full causal ordering of all d variables (local plan).

    Returns ``order`` (d,) int32 — order[p] is the variable at causal
    position p (order[0] = most exogenous).
    """
    return masked_order_impl(
        x, LocalReducer(backend=backend, interpret=interpret), unroll=unroll
    )


def _stage_schedule(d: int, frac: float = 0.25, min_stage: int = 8):
    """Static compaction schedule: [(width, n_steps), ...], sum n = d.

    Each stage runs ``n_steps`` ordering steps at logical width ``width``
    and then gathers the surviving columns into a ``width - n_steps``
    buffer. Smaller ``frac`` compacts more aggressively: total pair work
    approaches the sequential algorithm's d^3/3 instead of the masked
    scan's d^3 (frac=0.25 => ~0.43 d^3, a ~2.3x FLOP cut).
    """
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"compaction frac must be in (0, 1], got {frac}")
    if min_stage < 1:
        raise ValueError(f"min_stage must be >= 1, got {min_stage}")
    sched = []
    d_cur = d
    while d_cur > min_stage:
        n = max(1, int(round(d_cur * frac)))
        sched.append((d_cur, n))
        d_cur -= n
    if d_cur:
        sched.append((d_cur, d_cur))
    return tuple(sched)


def compact_order_impl(x, reducer, *, d=None, frac=0.25, min_stage=8):
    """In-trace staged compaction: one traced program, static stage shapes.

    The whole schedule is unrolled inside a single trace — every stage
    has a static width, so the function compiles exactly once and
    composes with ``vmap`` (each batch element compacts along its *own*
    surviving columns via a batched gather) and with ``shard_map``
    (columns are replicated across sample shards, so every shard gathers
    the same survivors; widths stay multiples of
    ``reducer.col_multiple``, i.e. the pair-axis size, with freed slots
    zeroed and inactive). Active-column arithmetic is identical to the
    full masked scan — inactive columns never influence active ones — so
    the returned order matches :func:`masked_order_impl` exactly.
    """
    width = x.shape[1]
    if d is None:
        d = width
    x = x.astype(jnp.float32)
    col_multiple = reducer.col_multiple
    labels = jnp.arange(width, dtype=jnp.int32)  # current column -> original
    active = jnp.arange(width) < d
    parts = []
    body = _scan_body(reducer)
    for w_logical, n_steps in _stage_schedule(d, frac, min_stage):
        (x, active), roots = jax.lax.scan(
            body, (x, active), None, length=n_steps
        )
        parts.append(labels[roots])
        keep = w_logical - n_steps
        if keep:
            keep_pad = _round_up(keep, col_multiple)
            # Surviving column indices in ascending order (stable under
            # vmap: distinct keys, inactive pushed past the end).
            idx = jnp.argsort(jnp.where(active, jnp.arange(width), width))
            idx = idx[:keep_pad]
            x = jnp.take(x, idx, axis=1)
            labels = labels[idx]
            if keep_pad != keep:
                colmask = jnp.arange(keep_pad) < keep
                x = jnp.where(colmask[None, :], x, 0.0)
                active = colmask
            else:
                active = jnp.ones((keep,), dtype=bool)
            width = keep_pad
    return jnp.concatenate(parts).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("backend", "interpret", "frac", "min_stage"),
)
def causal_order_compact(
    x, *, backend=None, interpret=None, frac=0.25, min_stage=8
):
    """Single-compile staged-compaction ordering (see impl docstring)."""
    return compact_order_impl(
        x, LocalReducer(backend=backend, interpret=interpret),
        frac=frac, min_stage=min_stage,
    )


def causal_order_staged(
    x, *, backend=None, interpret=None, min_stage=32
):
    """Deprecated alias of :func:`causal_order_compact`.

    The original host-driven staging (one re-jit per stage) is
    superseded by the in-trace compaction, which returns the identical
    order from a single compile and composes with ``vmap`` /
    ``shard_map``. This shim remains for one release cycle.
    """
    warnings.warn(
        "causal_order_staged is deprecated; use causal_order_compact "
        "(in-trace staged compaction, single compile, identical order).",
        DeprecationWarning,
        stacklevel=2,
    )
    return causal_order_compact(
        x, backend=backend, interpret=interpret,
        min_stage=max(int(min_stage), 1),
    )
