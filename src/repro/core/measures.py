"""Entropy / mutual-information measures used by the LiNGAM causal ordering.

Implements the maximum-entropy approximation of differential entropy from
Hyvarinen (1998), as used by DirectLiNGAM (Shimizu et al., 2011) and the
paper's Algorithm 1:

    H(u) ~= (1 + log(2*pi)) / 2
            - k1 * (E[log cosh u] - gamma)^2
            - k2 * (E[u * exp(-u^2 / 2)])^2

for a standardized (zero-mean, unit-variance) random variable ``u``.

The two expectations E[log cosh u] and E[u exp(-u^2/2)] are the *only*
sample-dependent quantities; everything else is O(1) postprocessing. The
Pallas kernel in ``repro.kernels`` computes exactly these two moments for
all variable pairs' regression residuals.
"""

from __future__ import annotations

import jax.numpy as jnp

# Constants of the Hyvarinen entropy approximation (same values as the
# reference lingam package and the paper's implementation).
K1 = 79.047
K2 = 7.4129
GAMMA = 0.37457

# H(standard normal) = (1 + log(2 pi)) / 2
_H_GAUSS = 0.5 * (1.0 + jnp.log(2.0 * jnp.pi))


def entropy_from_moments(m_logcosh, m_uexp):
    """Entropy approximation from the two nonlinear moments.

    Args:
      m_logcosh: E[log cosh u]   (any broadcastable shape)
      m_uexp:    E[u exp(-u^2/2)]
    Returns:
      H(u) with the same shape.
    """
    return (
        _H_GAUSS
        - K1 * (m_logcosh - GAMMA) ** 2
        - K2 * m_uexp**2
    )


# The single definition of the moment integrands shared by every
# execution plan lives in ``repro.kernels.nonlinearity`` (the kernels
# package must stay core-free); re-exported here so measure consumers
# keep one import site. Only the *reductions* over samples differ
# between plans (plain mean, chunked scan, psum over a mesh).
from repro.kernels.nonlinearity import nonlinear_terms  # noqa: F401,E402


def nonlinear_moments(u, axis=-1):
    """E[log cosh u] and E[u exp(-u^2/2)] along ``axis``."""
    logcosh, uexp = nonlinear_terms(u)
    return jnp.mean(logcosh, axis=axis), jnp.mean(uexp, axis=axis)


def entropy(u, axis=-1):
    """H(u) of standardized samples along ``axis``."""
    m1, m2 = nonlinear_moments(u, axis=axis)
    return entropy_from_moments(m1, m2)


def diff_mutual_info(h_xi, h_xj, h_ri_j, h_rj_i):
    """Difference of mutual information for the pair (i, j).

    Matches the paper's ``_diff_mutual_info``:
        (H(x_j) + H(r_i<-j / std)) - (H(x_i) + H(r_j<-i / std))
    Positive => i is more plausibly upstream of j.
    """
    return (h_xj + h_ri_j) - (h_xi + h_rj_i)
