"""Bootstrap confidence for discovered edges (paper §4 applications run
this in practice: gene networks / stock graphs are reported with edge
stability, not single point estimates).

Resamples rows with replacement, refits DirectLiNGAM per resample (the
accelerated ordering makes this affordable — the whole point of the
paper), and returns edge-presence probabilities plus coefficient
means/stds. Deterministic under a seed.

Two execution strategies share one on-device index matrix
(:func:`repro.core.batched.resample_indices`), so they fit *identical*
resamples and their summaries agree:

  * ``strategy="vmap"`` — the batched engine: ``vmap(fit_fn)`` over all
    resamples inside a single jitted program (the gather, every ordering
    scan, and every adjacency solve compile exactly once; the cheap edge
    statistics reduce host-side so threshold sweeps reuse the compile
    cache). By default it orders with in-trace staged compaction
    (``compaction="staged"``), which provably returns the same causal
    order as the full masked scan at ~2x fewer FLOPs — together with
    batching this is the multi-x throughput win measured by
    ``benchmarks/bench_bootstrap.py``.
  * ``strategy="loop"`` — the legacy host loop, one ``fit_fn`` call per
    resample in O(m * d) memory. Kept as the fallback for
    memory-constrained shapes (the vmap engine materializes the
    (n_sampling, m, d) resample stack) and as the equivalence oracle for
    the engine's tests.
  * ``strategy="auto"`` (default) — vmap when ~4x the resample stack
    (the program's live working set) fits ``max_vmap_bytes`` (default
    1 GiB), loop otherwise: paper-scale cells like (m=1e6, d=100,
    n=100) keep working instead of OOMing inside one 40 GB program.

Pass ``config=FitConfig(...)`` to pin every estimator setting explicitly
(both strategies honor it verbatim); ``model=DirectLiNGAM(...)`` adopts
*all* of the model's settings (backend, interpret, prune method/
threshold/kwargs) — not just the prune fields.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import batched
from repro.core.api import FitConfig, fit_fn


@dataclasses.dataclass
class BootstrapResult:
    edge_prob: np.ndarray    # (d, d) P(|B_ij| > threshold)
    coef_mean: np.ndarray    # (d, d) mean coefficient over resamples
    coef_std: np.ndarray     # (d, d)
    n_sampling: int

    def stable_edges(self, min_prob: float = 0.7):
        """[(i, j, prob, mean_coef)] sorted by probability."""
        idx = np.argwhere(self.edge_prob >= min_prob)
        out = [
            (int(i), int(j), float(self.edge_prob[i, j]),
             float(self.coef_mean[i, j]))
            for i, j in idx
        ]
        return sorted(out, key=lambda t: -t[2])


def _resolve_config(
    backend: str,
    model,
    config: Optional[FitConfig],
    strategy: str,
) -> FitConfig:
    """Estimator settings, in priority: explicit config > model > args.

    A passed model is adopted verbatim (including its ``compaction``).
    Only when neither config nor model is given does the strategy pick
    the ordering schedule: the vmap engine defaults to staged compaction
    (same order, ~2x fewer FLOPs); the loop fallback keeps the legacy
    full scan.
    """
    if config is not None:
        return config
    if model is not None:
        return model.to_config()
    compaction = "staged" if strategy == "vmap" else "none"
    return FitConfig(backend=backend, compaction=compaction)


def _summarize(coefs: np.ndarray, threshold: float) -> BootstrapResult:
    """Shared (strategy-independent) reduction of stacked coefficients."""
    n_sampling = coefs.shape[0]
    present = (np.abs(coefs) > threshold).astype(float).sum(axis=0)
    return BootstrapResult(
        edge_prob=present / n_sampling,
        coef_mean=coefs.mean(axis=0),
        coef_std=coefs.std(axis=0),
        n_sampling=n_sampling,
    )


def bootstrap_lingam(
    x,
    n_sampling: int = 20,
    threshold: float = 0.05,
    seed: int = 0,
    backend: str = "blocked",
    model=None,
    strategy: str = "auto",
    config: Optional[FitConfig] = None,
    max_vmap_bytes: int = 1 << 30,
) -> BootstrapResult:
    x = np.asarray(x, dtype=np.float32)
    m, d = x.shape
    if strategy == "auto":
        # The vmapped program holds several live (n_sampling, m, d) fp32
        # buffers at once (resample stack, scan carry, standardized
        # view), so budget ~4x the raw stack.
        est_bytes = 4 * (4 * n_sampling * m * d)
        strategy = "vmap" if est_bytes <= max_vmap_bytes else "loop"
    cfg = _resolve_config(backend, model, config, strategy)
    indices = batched.resample_indices(seed, n_sampling, m)

    if strategy == "vmap":
        results = batched.bootstrap_fits(x, indices, config=cfg)
        coefs = np.asarray(results.adjacency)
    elif strategy == "loop":
        idx = np.asarray(indices)
        coefs = np.empty((n_sampling, d, d), dtype=np.float32)
        for s in range(n_sampling):
            coefs[s] = np.asarray(fit_fn(x[idx[s]], cfg).adjacency)
    else:
        raise ValueError(f"unknown strategy: {strategy}")
    return _summarize(coefs, threshold)
