"""Bootstrap confidence for discovered edges (paper §4 applications run
this in practice: gene networks / stock graphs are reported with edge
stability, not single point estimates).

Resamples rows with replacement, refits DirectLiNGAM per resample (the
accelerated ordering makes this affordable — the whole point of the
paper), and returns edge-presence probabilities plus coefficient
means/stds. Deterministic under a seed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.direct_lingam import DirectLiNGAM


@dataclasses.dataclass
class BootstrapResult:
    edge_prob: np.ndarray    # (d, d) P(|B_ij| > threshold)
    coef_mean: np.ndarray    # (d, d) mean coefficient over resamples
    coef_std: np.ndarray     # (d, d)
    n_sampling: int

    def stable_edges(self, min_prob: float = 0.7):
        """[(i, j, prob, mean_coef)] sorted by probability."""
        idx = np.argwhere(self.edge_prob >= min_prob)
        out = [
            (int(i), int(j), float(self.edge_prob[i, j]),
             float(self.coef_mean[i, j]))
            for i, j in idx
        ]
        return sorted(out, key=lambda t: -t[2])


def bootstrap_lingam(
    x,
    n_sampling: int = 20,
    threshold: float = 0.05,
    seed: int = 0,
    backend: str = "blocked",
    model: Optional[DirectLiNGAM] = None,
) -> BootstrapResult:
    x = np.asarray(x, dtype=np.float32)
    m, d = x.shape
    rng = np.random.default_rng(seed)
    present = np.zeros((d, d))
    coefs = np.zeros((n_sampling, d, d), dtype=np.float32)
    for s in range(n_sampling):
        idx = rng.integers(0, m, size=m)
        mdl = model or DirectLiNGAM(backend=backend)
        mdl = DirectLiNGAM(
            backend=backend,
            prune_method=mdl.prune_method,
            prune_threshold=mdl.prune_threshold,
        )
        mdl.fit(x[idx])
        b = mdl.adjacency_
        coefs[s] = b
        present += (np.abs(b) > threshold).astype(float)
    return BootstrapResult(
        edge_prob=present / n_sampling,
        coef_mean=coefs.mean(axis=0),
        coef_std=coefs.std(axis=0),
        n_sampling=n_sampling,
    )
