"""Functional estimator core: pure, jittable DirectLiNGAM fits.

The stateful ``DirectLiNGAM`` / ``VarLiNGAM`` dataclasses are facades over
the two types here:

  * :class:`FitConfig` — frozen, hashable estimator settings. Passed as a
    *static* argument, so each distinct config compiles its own program.
  * :class:`FitResult` — a registered pytree (order, adjacency,
    diagnostics) that flows freely through ``jit``/``vmap``/``scan``.

``fit_fn(x, config)`` is the whole fit — ordering + adjacency +
diagnostics — as one traced program with no host round-trips, which is
what makes the batched engine in :mod:`repro.core.batched` possible:
``vmap(fit_fn)`` over resamples or datasets is a single compile.

    from repro.core import api
    res = api.fit_fn(x, api.FitConfig(backend="pallas"))
    res.order       # (d,) int32 causal order
    res.adjacency   # (d, d) f32 connection strengths
    res.resid_var   # (d,) f32 residual noise variances
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import ordering, pruning


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Static (hashable) configuration of one DirectLiNGAM fit.

    ``prune_kwargs`` is stored as a sorted tuple of (key, value) pairs so
    the config stays hashable; passing a dict is fine — it is normalized
    on construction.

    ``compaction`` selects the ordering schedule:
      * ``"none"``   — the full masked scan (d identical steps; exact
                       legacy behaviour of ``ordering.causal_order``).
      * ``"staged"`` — in-trace active-set compaction
                       (``ordering.causal_order_compact``): same order,
                       ~2x fewer FLOPs, still a single compile.
    """

    backend: str = "blocked"
    interpret: bool = True
    prune_method: str = "ols"
    prune_threshold: float = 0.0
    prune_kwargs: Tuple[Tuple[str, Any], ...] = ()
    compaction: str = "none"
    compaction_frac: float = 0.25
    min_stage: int = 8

    def __post_init__(self):
        if isinstance(self.prune_kwargs, dict):
            object.__setattr__(
                self, "prune_kwargs", tuple(sorted(self.prune_kwargs.items()))
            )

    @property
    def prune_kwargs_dict(self) -> Dict[str, Any]:
        return dict(self.prune_kwargs)


@dataclasses.dataclass
class FitResult:
    """One fit as a pytree. Under ``vmap`` every leaf gains the batch axis
    (``order``: (b, d), ``adjacency``: (b, d, d), ...)."""

    order: jax.Array       # (d,) int32 — position p holds the variable index
    adjacency: jax.Array   # (d, d) f32 — B[i, j] = effect of x_j on x_i
    resid_var: jax.Array   # (d,) f32 — Var(x_i - B_i x) diagnostic


jax.tree_util.register_dataclass(
    FitResult,
    data_fields=["order", "adjacency", "resid_var"],
    meta_fields=[],
)


def _order_for_config(x, config: FitConfig):
    if config.compaction == "none":
        return ordering._causal_order_impl(
            x, backend=config.backend, interpret=config.interpret
        )
    if config.compaction == "staged":
        return ordering._causal_order_compact_impl(
            x,
            backend=config.backend,
            interpret=config.interpret,
            frac=config.compaction_frac,
            min_stage=config.min_stage,
        )
    raise ValueError(f"unknown compaction: {config.compaction}")


def fit_impl(x, config: FitConfig) -> FitResult:
    """Unjitted trace body of :func:`fit_fn` (for callers composing larger
    programs — ``vmap`` in the batched engine, ``shard_map``, ...)."""
    x = x.astype(jnp.float32)
    order = _order_for_config(x, config)
    b = pruning.estimate_adjacency(
        x,
        order,
        method=config.prune_method,
        threshold=config.prune_threshold,
        **config.prune_kwargs_dict,
    )
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    resid = xc - xc @ b.T
    resid_var = jnp.mean(resid * resid, axis=0)
    return FitResult(order=order, adjacency=b, resid_var=resid_var)


@functools.partial(jax.jit, static_argnames=("config",))
def fit_fn(x, config: FitConfig = FitConfig()) -> FitResult:
    """Pure DirectLiNGAM fit: (m, d) data + static config -> FitResult.

    The entire fit is one traced program (ordering scan, adjacency solve,
    diagnostics); no host transfers occur until the caller reads a leaf.
    """
    return fit_impl(x, config)
