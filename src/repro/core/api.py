"""Functional estimator core: pure, jittable DirectLiNGAM fits.

The stateful ``DirectLiNGAM`` / ``VarLiNGAM`` dataclasses are facades over
the types here:

  * :class:`FitConfig` — frozen, hashable estimator settings. Passed as a
    *static* argument, so each distinct config compiles its own program.
  * :class:`Partition` — an optional mesh-partition spec inside the
    config: mesh axes/sizes, which axes shard the sample dimension, which
    axis tiles the (i, j) pair space, and the sample chunk size.
  * :class:`FitResult` — a registered pytree (order, adjacency,
    diagnostics) that flows freely through ``jit``/``vmap``/``scan``.

``fit_fn(x, config)`` is the whole fit — ordering + adjacency +
diagnostics — as one traced program with no host round-trips. The config
selects the execution plan; all three run the *same* ordering step
(:func:`repro.core.ordering.ordering_step`), differing only in how its
reductions execute:

  * **local** (``partition=None``) — plain ``jnp`` on one device.
  * **vmap** — the batched engine (:mod:`repro.core.batched`) maps the
    local plan over a leading dataset axis: ``vmap(fit_fn)`` over
    resamples or ensembles is a single compile.
  * **mesh** (``partition=Partition(...)``) — the fit compiles to a
    ``shard_map`` program (:mod:`repro.core.sharded`): samples sharded
    over the data axes (psum reductions), pair rows tiled over the model
    axis (all_gather), ordering with in-trace staged compaction, then
    row-sharded pruning — the d >> single-device-VMEM regime.

    from repro.core import api
    res = api.fit_fn(x, api.FitConfig(backend="pallas"))
    res.order       # (d,) int32 causal order
    res.adjacency   # (d, d) f32 connection strengths
    res.resid_var   # (d,) f32 residual noise variances

    mesh_cfg = api.FitConfig(
        compaction="staged",
        partition=api.Partition(mesh=(("data", 4), ("model", 2))),
    )
    res = api.fit_fn(x, mesh_cfg)   # same FitResult, 8 devices
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import compile_log
from repro.obs import profile as obs_profile

from . import ordering, pruning


@dataclasses.dataclass(frozen=True)
class Partition:
    """Static mesh-partition spec for the mesh execution plan.

    ``mesh`` is a tuple of (axis_name, size) pairs, e.g.
    ``(("data", 4), ("model", 2))`` — the product must not exceed
    ``jax.device_count()``. ``sample_axes`` shard the sample dimension
    (psum-reduced); ``pair_axis`` tiles the (i, j) pair rows
    (all_gathered). ``chunk`` bounds the per-device sample chunk of the
    moment pass; samples are padded to a multiple of
    ``n_sample_shards * chunk`` and variables to a multiple of the pair
    axis size (padded columns enter inactive and are never selected).
    ``fused_standardize`` folds standardization into the raw-X matmul
    (§Perf C2: one standardized-slab pass saved per ordering step).

    ``gather_finish`` picks the adjacency/diagnostics tail:
      * ``True`` (default) — reassemble the dataset on each device and
        reduce the covariance in a fixed replicated order: bit-exact
        against the local plan (the parity tests pin this), but peak
        per-device memory is the full (m, d) slab.
      * ``False`` — fully sharded finish: covariance psum-reduced over
        sample shards, residual diagnostics on local rows. Per-device
        memory stays O(m_local * d + d^2) — the true d >> one-device
        regime — at ulp-level (reduction-order) agreement instead of
        bit-exactness.
    """

    mesh: Tuple[Tuple[str, int], ...] = (("data", 1), ("model", 1))
    sample_axes: Tuple[str, ...] = ("data",)
    pair_axis: str = "model"
    chunk: int = 512
    fused_standardize: bool = False
    gather_finish: bool = True

    def __post_init__(self):
        if isinstance(self.mesh, dict):
            object.__setattr__(self, "mesh", tuple(self.mesh.items()))
        else:
            object.__setattr__(
                self, "mesh", tuple((str(a), int(s)) for a, s in self.mesh)
            )
        if isinstance(self.sample_axes, str):
            object.__setattr__(self, "sample_axes", (self.sample_axes,))
        else:
            object.__setattr__(self, "sample_axes", tuple(self.sample_axes))
        names = [a for a, _ in self.mesh]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axis names in mesh {self.mesh}")
        for ax in (*self.sample_axes, self.pair_axis):
            if ax not in names:
                raise ValueError(f"axis {ax!r} not in mesh {self.mesh}")
        if self.pair_axis in self.sample_axes:
            # An overlapping spec would psum different pair-row tiles
            # together (silently wrong moments), never just run slower.
            raise ValueError(
                f"pair_axis {self.pair_axis!r} must be disjoint from "
                f"sample_axes {self.sample_axes}"
            )


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Static (hashable) configuration of one DirectLiNGAM fit.

    ``prune_kwargs`` is stored as a sorted tuple of (key, value) pairs so
    the config stays hashable; passing a dict is fine — it is normalized
    on construction.

    ``compaction`` selects the ordering schedule:
      * ``"none"``   — the full masked scan (d identical steps; exact
                       legacy behaviour of ``ordering.causal_order``).
      * ``"staged"`` — in-trace active-set compaction
                       (``ordering.causal_order_compact``): same order,
                       ~2x fewer FLOPs, still a single compile. On a
                       mesh, stage widths stay multiples of the pair
                       axis size.

    ``partition`` selects the execution plan: ``None`` for the local
    (single-device / vmap) plan, a :class:`Partition` for the
    ``shard_map`` mesh plan.

    ``moment_chunk`` (local/vmap plans; ``blocked`` or ``pallas``
    backend) accumulates each ordering step's pairwise moments over
    (moment_chunk, d) sample slabs, bounding the per-step residual
    intermediate at O(chunk * d^2) — the streaming subsystem's
    rolling-window refits set this to the stream chunk size. The mesh
    plan chunks through ``Partition.chunk`` instead and ignores it.

    ``backend=None`` lets the kernel registry pick (pallas on
    accelerators, blocked elsewhere) and ``interpret=None`` resolves to
    interpret-only-when-no-accelerator. ``tune`` selects how block
    shapes/variants are decided (:mod:`repro.kernels.tune`):
    ``"off"`` — deterministic heuristic, no tuning-table reads (the
    offline mode); ``"cache"`` (default) — tuned plans from the
    persistent table, heuristic fallback, never measures; ``"auto"`` —
    timed search on a table miss, persisted to the user overlay. Tuned
    and heuristic plans are bit-identical in output (the dispatch
    parity contract), so ``tune`` never changes results — only speed.
    """

    backend: Optional[str] = None
    interpret: Optional[bool] = None
    prune_method: str = "ols"
    prune_threshold: float = 0.0
    prune_kwargs: Tuple[Tuple[str, Any], ...] = ()
    compaction: str = "none"
    compaction_frac: float = 0.25
    min_stage: int = 8
    partition: Optional[Partition] = None
    moment_chunk: Optional[int] = None
    tune: str = "cache"

    def __post_init__(self):
        if isinstance(self.prune_kwargs, dict):
            object.__setattr__(
                self, "prune_kwargs", tuple(sorted(self.prune_kwargs.items()))
            )
        if self.tune not in ("off", "cache", "auto"):
            raise ValueError(
                f"tune must be 'off', 'cache', or 'auto', got {self.tune!r}"
            )
        if self.moment_chunk is not None:
            if self.backend not in (None, "blocked", "pallas"):
                raise ValueError(
                    "moment_chunk requires the blocked or pallas backend "
                    f"(chunk accumulation has no {self.backend!r} variant)"
                )
            if self.moment_chunk < 1:
                raise ValueError(
                    f"moment_chunk must be >= 1, got {self.moment_chunk}"
                )

    @property
    def prune_kwargs_dict(self) -> Dict[str, Any]:
        return dict(self.prune_kwargs)


@dataclasses.dataclass
class FitResult:
    """One fit as a pytree. Under ``vmap`` every leaf gains the batch axis
    (``order``: (b, d), ``adjacency``: (b, d, d), ...)."""

    order: jax.Array       # (d,) int32 — position p holds the variable index
    adjacency: jax.Array   # (d, d) f32 — B[i, j] = effect of x_j on x_i
    resid_var: jax.Array   # (d,) f32 — Var(x_i - B_i x) diagnostic


jax.tree_util.register_dataclass(
    FitResult,
    data_fields=["order", "adjacency", "resid_var"],
    meta_fields=[],
)


def _order_for_config(x, config: FitConfig):
    reducer = ordering.LocalReducer(
        backend=config.backend,
        interpret=config.interpret,
        moment_chunk=config.moment_chunk,
        tune=config.tune,
    )
    if config.compaction == "none":
        return ordering.masked_order_impl(x, reducer)
    if config.compaction == "staged":
        return ordering.compact_order_impl(
            x,
            reducer,
            frac=config.compaction_frac,
            min_stage=config.min_stage,
        )
    raise ValueError(f"unknown compaction: {config.compaction}")


def finish_fit(x, order, config: FitConfig) -> FitResult:
    """Adjacency + residual diagnostics given the causal order.

    Shared tail of every plan: the mesh plan runs the sharded ordering
    and then this exact computation with its OLS row solves tiled over
    the pair axis via ``pruning.ols_rows`` — identical per-row
    arithmetic, so the plans' coefficients agree to the ulp-level
    lowering differences of batched solves (exactly, at the parity
    cells the tests pin).
    """
    b = pruning.estimate_adjacency(
        x,
        order,
        method=config.prune_method,
        threshold=config.prune_threshold,
        **config.prune_kwargs_dict,
    )
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    resid = xc - xc @ b.T
    resid_var = jnp.mean(resid * resid, axis=0)
    return FitResult(order=order, adjacency=b, resid_var=resid_var)


def fit_impl(x, config: FitConfig) -> FitResult:
    """Unjitted trace body of the local plan (for callers composing
    larger programs — ``vmap`` in the batched engine, ...).

    The stage spans here execute at *trace time* only (once per
    compile; tagged ``[trace]`` in the span tree) — they account for
    where trace construction goes, add nothing to the compiled
    program, and never run in steady state.
    """
    compile_log.record("core.fit", shape=x.shape, config=config)
    x = x.astype(jnp.float32)
    with obs.span("fit.ordering", d=x.shape[-1],
                  compaction=config.compaction):
        order = _order_for_config(x, config)
    with obs.span("fit.pruning", method=config.prune_method):
        return finish_fit(x, order, config)


@functools.partial(jax.jit, static_argnames=("config",))
def _fit_local(x, config: FitConfig) -> FitResult:
    return fit_impl(x, config)


def fit_fn(x, config: FitConfig = FitConfig()) -> FitResult:
    """Pure DirectLiNGAM fit: (m, d) data + static config -> FitResult.

    The entire fit is one traced program (ordering scan, adjacency solve,
    diagnostics); no host transfers occur until the caller reads a leaf.
    With ``config.partition`` set, the program is a ``shard_map`` over
    the configured mesh (built from the process's devices) and returns
    the same ``FitResult`` pytree — bit-identical at the parity cells
    pinned by ``tests/test_mesh_fit.py``, and agreeing to fp32
    reduction order (ulps) in general.
    """
    if config.partition is not None:
        from . import sharded

        with obs.span("fit.mesh", m=x.shape[0], d=x.shape[1]):
            return sharded.fit_sharded(x, config)
    with obs.span("fit.local", m=x.shape[0], d=x.shape[1]):
        # Same (op, shape, config) signature as the compile_log.record
        # inside fit_impl, so cost rows join compile events.
        return obs_profile.call(
            _fit_local, x, config,
            op="core.fit", shape=x.shape, config=config,
        )


_STATS_EPS = 1e-12


def fit_impl_from_stats(x, mean, cov, config: FitConfig) -> FitResult:
    """Unjitted trace body of the from-stats fit (vmapped by
    ``batched.fit_many_from_stats``)."""
    compile_log.record("core.fit_from_stats", shape=x.shape, config=config)
    x = x.astype(jnp.float32)
    mean = mean.astype(jnp.float32)
    cov = cov.astype(jnp.float32)
    var = jnp.maximum(jnp.diagonal(cov), _STATS_EPS)
    x0 = (x - mean[None, :]) * jax.lax.rsqrt(var)[None, :]
    order = _order_for_config(x0, config)
    b = pruning.estimate_adjacency_from_cov(
        cov,
        order,
        method=config.prune_method,
        threshold=config.prune_threshold,
        **config.prune_kwargs_dict,
    )
    r = jnp.eye(b.shape[0], dtype=b.dtype) - b
    resid_var = jnp.maximum(jnp.einsum("ij,jk,ik->i", r, cov, r), 0.0)
    return FitResult(order=order, adjacency=b, resid_var=resid_var)


@functools.partial(jax.jit, static_argnames=("config",))
def _fit_from_stats_local(x, mean, cov, config: FitConfig) -> FitResult:
    return fit_impl_from_stats(x, mean, cov, config)


def fit_from_stats(
    x, mean, cov, config: FitConfig = FitConfig()
) -> FitResult:
    """DirectLiNGAM fit that reuses precomputed sufficient statistics.

    The streaming entry point: ``mean``/``cov`` are the (d,) mean and
    (d, d) ddof=0 covariance of ``x`` — maintained incrementally by the
    rolling moment store (:mod:`repro.stream.stats`) rather than
    recomputed from the rows. They replace every data pass the fit can
    avoid:

      * the initial standardization uses the provided moments (the
        in-scan re-standardization then operates on already-clean
        columns — the ordering is affine-invariant per column);
      * adjacency pruning solves straight from ``cov``
        (:func:`repro.core.pruning.estimate_adjacency_from_cov`) — no
        O(m d^2) covariance matmul;
      * residual diagnostics come from ``diag((I-B) cov (I-B)^T)``,
        which equals the empirical residual variance exactly when
        ``cov`` is the sample covariance of ``x``.

    Only the nonlinear ordering moments still read the rows (they are
    standardization-dependent); ``config.moment_chunk`` bounds that pass
    at O(chunk) sample slabs. The mesh plan has no from-stats variant —
    partitioned configs are rejected with a pointer to ``fit_fn``.
    """
    if config.partition is not None:
        raise ValueError(
            "fit_from_stats runs the local/vmap plans only; the mesh "
            "plan recomputes statistics shard-locally — drop "
            "config.partition or use fit_fn."
        )
    x = jnp.asarray(x)
    return obs_profile.call(
        _fit_from_stats_local, x, jnp.asarray(mean), jnp.asarray(cov), config,
        op="core.fit_from_stats", shape=x.shape, config=config,
    )
