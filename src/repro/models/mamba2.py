"""Mamba2 block — SSD (state-space duality) chunked algorithm + O(1) decode.

Implements the selective state-space layer of Mamba2 (Dao & Gu, 2024):

    h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t (x) x_t)
    y_t = C_t . h_t + D * x_t

Training/prefill uses the chunked SSD form: within a chunk of Q tokens the
quadratic "attention-like" term runs on the MXU; across chunks a linear
recurrence carries the (H, P, N) state. Decode is the single-step
recurrence with a rolling depthwise-conv state.

Shapes: x (B, L, D_inner) viewed as (B, L, H, P) heads; B/C (B, L, G, N)
broadcast over the H//G heads of each group; A is a per-head scalar.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers


def conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba(cfg: ArchConfig, key):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    gdim = cfg.ssm_groups * cfg.ssm_state
    cdim = conv_dim(cfg)
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * di + 2 * gdim + h
    return {
        "in_proj": layers._init(ks[0], (d, d_in_proj)),
        "conv_w": layers._init(ks[1], (cfg.ssm_conv, cdim), scale=0.5),
        "conv_b": jnp.zeros((cdim,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": layers._init(ks[2], (di, d)),
    }


def _segsum(a):
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums:
    out[l, s] = sum_{t=s+1..l} a[t], -inf above the diagonal."""
    q = a.shape[-1]
    t = jnp.cumsum(a, axis=-1)
    seg = t[..., :, None] - t[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xh, dt, a, b_in, c_in, chunk, initial_state=None):
    """Chunked SSD scan.

    xh: (B, L, H, P); dt: (B, L, H) (post-softplus); a: (H,) negative;
    b_in/c_in: (B, L, G, N). Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = xh.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    hg = h // g

    # Broadcast groups over heads and scale x by dt (fp32 decay math).
    bh = jnp.repeat(b_in, hg, axis=2)  # (B, L, H, N)
    ch = jnp.repeat(c_in, hg, axis=2)
    dta = (dt * a[None, None, :]).astype(jnp.float32)  # (B, L, H), negative
    xbar = xh * dt[..., None].astype(xh.dtype)

    def tochunks(t):  # (B, L, ...) -> (B, nc, Q, ...)
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xc, bc, cc = tochunks(xbar), tochunks(bh), tochunks(ch)
    dtac = tochunks(dta).transpose(0, 3, 1, 2)  # (B, H, nc, Q)
    a_cum = jnp.cumsum(dtac, axis=-1)  # (B, H, nc, Q)

    # Intra-chunk (quadratic, MXU): Y_diag = (C B^T o L) X
    lmat = jnp.exp(_segsum(dtac)).astype(xh.dtype)  # (B,H,nc,Q,Q)
    y_diag = jnp.einsum(
        "bclhn,bcshn,bhcls,bcshp->bclhp", cc, bc, lmat, xc
    )

    # Chunk states: B^T X weighted by remaining decay within the chunk.
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum).astype(xh.dtype)  # (B,H,nc,Q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # Inter-chunk recurrence over nc chunks.
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B, H, nc)
    if initial_state is None:
        init = jnp.zeros((bsz, h, p, n), xh.dtype)
    else:
        init = initial_state.astype(xh.dtype)

    def scan_fn(carry, inp):
        st, dec = inp  # st: (B,H,P,N), dec: (B,H)
        new = carry * dec[..., None, None].astype(xh.dtype) + st
        return new, carry  # emit the state *entering* this chunk

    (final_state, prev_states) = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # Inter-chunk output: C_t . (decayed incoming state)
    state_decay = jnp.exp(a_cum).astype(xh.dtype)  # (B,H,nc,Q)
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", cc, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, final_state


def _split_xbc(cfg, xbc):
    di = cfg.d_inner
    gdim = cfg.ssm_groups * cfg.ssm_state
    x = xbc[..., :di]
    b = xbc[..., di : di + gdim]
    c = xbc[..., di + gdim :]
    return x, b, c


def _causal_conv(cfg, p, xbc):
    """Depthwise causal conv over (B, L, C_dim) + SiLU."""
    k = cfg.ssm_conv
    w = p["conv_w"].astype(xbc.dtype)  # (k, cdim)
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(k)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _gated_out(cfg, p, y, z):
    """RMSNorm(y * silu(z)) then out-projection."""
    dt = y.dtype
    gated = y * jax.nn.silu(z)
    gf = gated.astype(jnp.float32)
    ms = jnp.mean(gf * gf, axis=-1, keepdims=True)
    normed = (gf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"]).astype(dt)
    return jnp.einsum("bld,de->ble", normed, p["out_proj"].astype(dt))


def apply_mamba(cfg: ArchConfig, p, x, *, return_cache: bool = False):
    """Full-sequence forward. x: (B, L, D). Returns (out, cache | None)."""
    bsz, l, _ = x.shape
    h = cfg.ssm_heads
    dt_type = x.dtype
    proj = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dt_type))
    z = proj[..., : cfg.d_inner]
    xbc = proj[..., cfg.d_inner : -h]
    dt_raw = proj[..., -h:]

    xbc_conv = _causal_conv(cfg, p, xbc)
    xin, b_in, c_in = _split_xbc(cfg, xbc_conv)
    xh = xin.reshape(bsz, l, h, cfg.ssm_headdim)
    b_in = b_in.reshape(bsz, l, cfg.ssm_groups, cfg.ssm_state)
    c_in = c_in.reshape(bsz, l, cfg.ssm_groups, cfg.ssm_state)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :]
    )
    a = -jnp.exp(p["a_log"])  # (H,)

    # Pad L up to a chunk multiple if needed (zeros don't affect the scan:
    # dt=softplus(bias) > 0 but x=0 contributes nothing; outputs sliced off).
    q = cfg.ssm_chunk
    l_pad = (q - l % q) % q
    if l_pad:
        xh = jnp.pad(xh, ((0, 0), (0, l_pad), (0, 0), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, l_pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, l_pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, l_pad), (0, 0)))

    y, final_state = ssd_chunked(xh, dt, a, b_in, c_in, q)
    y = y[:, :l]
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh[:, :l]
    y = y.reshape(bsz, l, cfg.d_inner)
    out = _gated_out(cfg, p, y, z)

    cache = None
    if return_cache:
        k = cfg.ssm_conv
        tail = xbc[:, -(k - 1) :, :] if l >= k - 1 else jnp.pad(
            xbc, ((0, 0), (k - 1 - l, 0), (0, 0))
        )
        cache = {"conv": tail, "ssm": final_state}
    return out, cache


def apply_mamba_decode(cfg: ArchConfig, p, x, cache):
    """Single-token decode. x: (B, 1, D); cache: {conv (B,k-1,cdim),
    ssm (B,H,P,N)}. Returns (out (B,1,D), new_cache)."""
    bsz = x.shape[0]
    h = cfg.ssm_heads
    dtp = x.dtype
    proj = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dtp))
    z = proj[..., : cfg.d_inner]
    xbc = proj[..., cfg.d_inner : -h]  # (B, 1, cdim)
    dt_raw = proj[..., -h:]

    # rolling conv state
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, k, cdim)
    w = p["conv_w"].astype(dtp)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(dtp)
    xbc_t = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]

    xin, b_in, c_in = _split_xbc(cfg, xbc_t)
    xh = xin.reshape(bsz, h, cfg.ssm_headdim)
    b_in = b_in.reshape(bsz, cfg.ssm_groups, cfg.ssm_state)
    c_in = c_in.reshape(bsz, cfg.ssm_groups, cfg.ssm_state)
    hg = h // cfg.ssm_groups
    bh = jnp.repeat(b_in, hg, axis=1)  # (B, H, N)
    ch = jnp.repeat(c_in, hg, axis=1)
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :]
    )  # (B, H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a[None, :]).astype(dtp)  # (B, H)

    state = cache["ssm"]
    inc = jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None].astype(dtp), bh)
    new_state = state * da[..., None, None] + inc
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    y = y + p["d_skip"].astype(dtp)[None, :, None] * xh
    y = y.reshape(bsz, 1, cfg.d_inner)
    out = _gated_out(cfg, p, y, z)
    return out, {"conv": new_conv, "ssm": new_state}
