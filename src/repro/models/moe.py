"""Mixture-of-Experts layer: top-k routing with capacity, two dispatch
implementations, shared experts, and expert padding.

* ``scatter`` (default): tokens are moved into the (E, C) expert buffer with
  a batched scatter-add and gathered back — O(tokens * D) data movement, no
  fake FLOPs. This is the TPU-friendly dropless-ish path; when experts are
  sharded over the ``model`` axis XLA lowers the shuffle to all-to-all
  style collectives.
* ``einsum`` (GShard classic): one-hot dispatch/combine tensors
  (G, S, E, C). Kept for §Perf comparison — its dispatch einsum inflates
  HLO FLOPs by G*S*E*C*D.

Expert-count padding: routed experts are padded up to a multiple of 16
(the model-axis size) when E >= 16 — e.g. qwen2-moe's 60 -> 64 — with the
padded experts' router logits pinned to -inf so they are never selected.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, round_up
from repro.models import layers


def n_experts_padded(cfg: ArchConfig) -> int:
    e = cfg.n_experts
    return round_up(e, 16) if e >= 16 else e


def init_moe(cfg: ArchConfig, key):
    e = n_experts_padded(cfg)
    d, f = cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": layers._init(ks[0], (d, e), scale=0.02),
        "w_gate": layers._init(ks[1], (e, d, f)),
        "w_up": layers._init(ks[2], (e, d, f)),
        "w_down": layers._init(ks[3], (e, f, d)),
    }
    if cfg.n_shared_experts > 0:
        f_sh = cfg.n_shared_experts * cfg.d_ff_expert
        p["shared"] = layers.init_mlp(cfg, ks[4], d_ff=f_sh)
    return p


def _group(x, group_size=512):
    """(T, D) -> (G, S, D) with S | T."""
    t = x.shape[0]
    s = group_size if t % group_size == 0 else t
    return x.reshape(t // s, s, x.shape[-1]), s


def _route(cfg: ArchConfig, p, xg):
    """Router probabilities and top-k assignment. xg: (G, S, D)."""
    e_pad = p["router"].shape[1]
    logits = jnp.einsum(
        "gsd,de->gse", xg.astype(jnp.float32), p["router"]
    )
    if e_pad > cfg.n_experts:  # mask padded experts
        pad_mask = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.n_experts_active)  # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    return probs, gate_vals, idx


def _positions_in_expert(idx, e_pad, capacity):
    """GShard rank-ordered slot assignment.

    idx: (G, S, k) expert choice per token per rank. Returns
    (pos, keep): pos (G, S, k) slot id within the expert, keep (G, S, k)
    bool for tokens that fit under capacity.
    """
    g, s, k = idx.shape
    counts = jnp.zeros((g, e_pad), jnp.int32)
    pos_list, keep_list = [], []
    for r in range(k):
        onehot = jax.nn.one_hot(idx[:, :, r], e_pad, dtype=jnp.int32)  # (G,S,E)
        within = jnp.cumsum(onehot, axis=1) - onehot  # tokens before me, this rank
        pos_r = jnp.sum(onehot * (within + counts[:, None, :]), axis=-1)
        keep_r = pos_r < capacity
        pos_list.append(pos_r)
        keep_list.append(keep_r)
        counts = counts + jnp.sum(onehot, axis=1)
    return jnp.stack(pos_list, -1), jnp.stack(keep_list, -1)


def _expert_ffn(cfg, p, xe):
    """xe: (G, E, C, D) -> (G, E, C, D) via per-expert SwiGLU/GeLU."""
    dt = xe.dtype
    if cfg.mlp == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
        up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
        h = jax.nn.silu(gate) * up
    else:
        up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
        h = (
            jnp.square(jax.nn.relu(up))
            if cfg.mlp == "squared_relu"
            else jax.nn.gelu(up)
        )
    return jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))


def apply_moe(cfg: ArchConfig, p, x, *, impl: str = "scatter",
              group_size: int = 512):
    """x: (B, S, D) -> (out, aux) where aux = load-balance loss scalar."""
    b, s, d = x.shape
    e_pad = p["router"].shape[1]
    k = cfg.n_experts_active
    xf = x.reshape(b * s, d)
    xg, sg = _group(xf, group_size)  # (G, S_g, D)
    g = xg.shape[0]
    capacity = max(1, math.ceil(sg * k / cfg.n_experts * cfg.capacity_factor))

    probs, gates, idx = _route(cfg, p, xg)
    pos, keep = _positions_in_expert(idx, e_pad, capacity)

    # Switch-style load-balance aux loss (rank-0 assignments).
    frac = jnp.mean(
        jax.nn.one_hot(idx[:, :, 0], e_pad, dtype=jnp.float32), axis=(0, 1)
    )
    aux = cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    if impl == "scatter":
        dest = idx * capacity + pos  # (G, S, k) flat slot in (E*C)
        dest = jnp.where(keep, dest, e_pad * capacity)  # overflow slot
        buf = jnp.zeros((g, e_pad * capacity + 1, d), x.dtype)

        def scatter_one(bufg, destg, xgg, keepg):
            upd = xgg[:, None, :] * keepg[..., None].astype(xgg.dtype)
            for r in range(k):
                bufg = bufg.at[destg[:, r]].add(upd[:, r])
            return bufg

        buf = jax.vmap(scatter_one)(buf, dest, xg, keep)
        xe = buf[:, : e_pad * capacity].reshape(g, e_pad, capacity, d)
        ye = _expert_ffn(cfg, p, xe)
        yflat = ye.reshape(g, e_pad * capacity, d)
        yflat = jnp.concatenate(
            [yflat, jnp.zeros((g, 1, d), x.dtype)], axis=1
        )

        def gather_one(yg, destg, gateg, keepg):
            out = jnp.zeros((sg, d), x.dtype)
            for r in range(k):
                w = (gateg[:, r] * keepg[:, r]).astype(x.dtype)
                out = out + yg[destg[:, r]] * w[:, None]
            return out

        out = jax.vmap(gather_one)(yflat, dest, gates, keep)
    elif impl == "einsum":
        gk = (gates * keep).astype(x.dtype)  # (G,S,k)
        oh_e = jax.nn.one_hot(idx, e_pad, dtype=x.dtype)  # (G,S,k,E)
        oh_c = jax.nn.one_hot(pos, capacity, dtype=x.dtype)  # (G,S,k,C)
        combine = jnp.einsum("gsk,gske,gskc->gsec", gk, oh_e, oh_c)
        dispatch = (combine > 0).astype(x.dtype)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
        ye = _expert_ffn(cfg, p, xe)
        out = jnp.einsum("gsec,gecd->gsd", combine, ye)
    else:
        raise ValueError(impl)

    out = out.reshape(b, s, d)
    if cfg.n_shared_experts > 0:
        out = out + layers.apply_mlp(cfg, p["shared"], x)
    return out, aux
