"""Model assembly for all assigned architecture families.

Every architecture is expressed as ``n_groups`` repetitions of a layer
*pattern* (period = 1 for uniform stacks, 5 for the vision cross-attn
interleave, 8 for jamba's 1:7 mamba:attn hybrid). Parameters of each
pattern position are stacked over groups and the group loop is a
``lax.scan`` — HLO size stays O(pattern), not O(n_layers), which keeps
512-device dry-run compiles tractable even for the 96-layer 340B config.

Public entry points:
  init_params(cfg, key, max_seq)          -> param pytree (allocating)
  forward(cfg, params, tokens, ...)       -> (logits, aux)   [train path]
  prefill(cfg, params, tokens, ...)       -> (last_logits, cache)
  decode_step(cfg, params, token, cache, pos) -> (logits, new_cache)
  lm_loss(cfg, params, batch)             -> scalar loss
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, mamba2, moe


# ---------------------------------------------------------------- pattern
@dataclasses.dataclass(frozen=True)
class LayerDesc:
    mixer: str  # "attn" | "mamba"
    cross: bool = False
    ffn: Optional[str] = None  # "mlp" | "moe" | None


def layer_pattern(cfg: ArchConfig) -> List[LayerDesc]:
    """The repeating layer pattern for this architecture."""
    fam = cfg.family
    if fam in ("dense",):
        return [LayerDesc("attn", ffn="mlp")]
    if fam == "moe":
        return [LayerDesc("attn", ffn="moe")]
    if fam == "ssm":
        return [LayerDesc("mamba", ffn=None)]
    if fam == "vlm":
        period = cfg.cross_attn_every
        descs = [LayerDesc("attn", ffn="mlp") for _ in range(period)]
        descs[period - 1] = LayerDesc("attn", cross=True, ffn="mlp")
        return descs
    if fam == "hybrid":
        period = cfg.attn_every
        descs = []
        for i in range(period):
            mixer = "attn" if i == period // 2 else "mamba"
            ffn = "moe" if i % cfg.moe_every == cfg.moe_offset else "mlp"
            descs.append(LayerDesc(mixer, ffn=ffn))
        return descs
    if fam == "audio":  # decoder pattern; encoder handled separately
        return [LayerDesc("attn", cross=True, ffn="mlp")]
    raise ValueError(fam)


def n_groups(cfg: ArchConfig) -> int:
    period = len(layer_pattern(cfg))
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    return cfg.n_layers // period


# ---------------------------------------------------------------- init
def _init_layer(cfg: ArchConfig, key, desc: LayerDesc) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": layers.init_norm(cfg, cfg.d_model)}
    if desc.mixer == "attn":
        p["attn"] = layers.init_attention(cfg, ks[0])
    else:
        p["mamba"] = mamba2.init_mamba(cfg, ks[0])
    if desc.cross:
        p["ln_x"] = layers.init_norm(cfg, cfg.d_model)
        p["xattn"] = layers.init_attention(cfg, ks[1], cross=True)
    if desc.ffn == "mlp":
        p["ln2"] = layers.init_norm(cfg, cfg.d_model)
        p["mlp"] = layers.init_mlp(cfg, ks[2])
    elif desc.ffn == "moe":
        p["ln2"] = layers.init_norm(cfg, cfg.d_model)
        p["moe"] = moe.init_moe(cfg, ks[2])
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key, max_seq: int = 0) -> Dict[str, Any]:
    pattern = layer_pattern(cfg)
    ng = n_groups(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(cfg, keys[0]),
        "ln_f": layers.init_norm(cfg, cfg.d_model),
        "head": layers.init_lm_head(cfg, keys[1]),
    }
    gkeys = jax.random.split(keys[2], ng * len(pattern)).reshape(
        ng, len(pattern)
    )
    groups = []
    for pos, desc in enumerate(pattern):
        groups.append(
            _stack([_init_layer(cfg, gkeys[g, pos], desc) for g in range(ng)])
        )
    params["groups"] = groups

    if cfg.rope_theta == 0.0 and max_seq > 0:
        params["pos_embed"] = layers.init_pos_embedding(cfg, keys[3], max_seq)

    if cfg.encoder_layers > 0:  # whisper encoder (frontend embeddings stub)
        enc_cfg = cfg
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        enc_layers = [
            _init_layer(enc_cfg, ekeys[i], LayerDesc("attn", ffn="mlp"))
            for i in range(cfg.encoder_layers)
        ]
        params["encoder"] = {
            "layers": _stack(enc_layers),
            "ln_f": layers.init_norm(cfg, cfg.d_model),
            "pos": layers.init_pos_embedding(cfg, keys[5], cfg.n_frontend_tokens)[
                "pos"
            ],
        }
    return params


# ---------------------------------------------------------------- encoder
def _run_encoder(cfg: ArchConfig, params, frames):
    """frames: (B, T, D) stub embeddings -> (B, T, D) encoder output."""
    enc = params["encoder"]
    x = frames.astype(layers.cdtype(cfg))
    x = x + enc["pos"].astype(x.dtype)[None, : x.shape[1], :]
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        h = carry
        a, _ = layers.attention(
            cfg, lp["attn"], layers.apply_norm(cfg, lp["ln1"], h),
            positions=positions, causal=False,
        )
        h = h + a
        h = h + layers.apply_mlp(
            cfg, lp["mlp"], layers.apply_norm(cfg, lp["ln2"], h)
        )
        return h, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return layers.apply_norm(cfg, enc["ln_f"], x)


# ---------------------------------------------------------------- core stack
def _layer_apply(cfg, desc: LayerDesc, lp, x, *, positions, enc_out,
                 cache, cache_pos, moe_impl):
    """One pattern-position layer. Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}
    h = layers.apply_norm(cfg, lp["ln1"], x)
    if desc.mixer == "attn":
        c = cache.get("attn") if cache else None
        a, nc = layers.attention(
            cfg, lp["attn"], h, positions=positions, causal=True,
            cache=c, cache_pos=cache_pos,
        )
        if nc is not None:
            new_cache["attn"] = nc
        x = x + a
    else:
        if cache is not None and "mamba" in cache and h.shape[1] == 1:
            # decode: single-step recurrence against the carried state
            a, nc = mamba2.apply_mamba_decode(cfg, lp["mamba"], h, cache["mamba"])
            new_cache["mamba"] = nc
        else:
            # train (cache=None) or prefill (fresh state, emit cache)
            want_cache = cache is not None
            a, nc = mamba2.apply_mamba(cfg, lp["mamba"], h, return_cache=want_cache)
            if want_cache:
                new_cache["mamba"] = nc
        x = x + a
    if desc.cross:
        hx = layers.apply_norm(cfg, lp["ln_x"], x)
        a, _ = layers.attention(
            cfg, lp["xattn"], hx, positions=positions, causal=False,
            kv_x=enc_out,
        )
        x = x + a
    if desc.ffn == "mlp":
        h2 = layers.apply_norm(cfg, lp["ln2"], x)
        x = x + layers.apply_mlp(cfg, lp["mlp"], h2)
    elif desc.ffn == "moe":
        h2 = layers.apply_norm(cfg, lp["ln2"], x)
        mo, a_loss = moe.apply_moe(cfg, lp["moe"], h2, impl=moe_impl)
        x = x + mo
        aux = aux + a_loss
    return x, new_cache, aux


def _run_stack(cfg: ArchConfig, params, x, *, positions, enc_out=None,
               caches=None, cache_pos=None, moe_impl="scatter"):
    """Scan the group stack. caches: None (train) or list per pattern pos of
    stacked-over-group cache pytrees. Returns (x, new_caches, aux_sum)."""
    pattern = layer_pattern(cfg)

    def group_body(carry, xs):
        h, aux = carry
        new_caches_g = []
        for pos, desc in enumerate(pattern):
            lp = xs[pos]
            c_g = xs[len(pattern) + pos] if caches is not None else None
            h, nc, a = _layer_apply(
                cfg, desc, lp, h, positions=positions, enc_out=enc_out,
                cache=c_g, cache_pos=cache_pos, moe_impl=moe_impl,
            )
            aux = aux + a
            new_caches_g.append(nc)
        return (h, aux), tuple(new_caches_g)

    body = group_body
    if cfg.remat:
        # "full": recompute everything (min memory, 2x fwd FLOPs);
        # "dots": save matmul outputs, recompute only elementwise/norms
        # (§Perf E — near-1x FLOPs at moderate activation memory).
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat_policy == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        body = jax.checkpoint(group_body, policy=policy)

    xs = tuple(params["groups"])
    if caches is not None:
        xs = xs + tuple(caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, (list(new_caches) if caches is not None else None), aux


# ---------------------------------------------------------------- train fwd
def forward(cfg: ArchConfig, params, tokens, *, frontend=None,
            moe_impl="scatter"):
    """Training forward. tokens: (B, S) int32; frontend: (B, T, D) stub
    embeddings for audio/vlm. Returns (logits (B,S,V), aux)."""
    x = layers.embed(cfg, params["embed"], tokens)
    b, s = tokens.shape
    positions = jnp.arange(s)
    if cfg.rope_theta == 0.0 and "pos_embed" in params:
        x = x + params["pos_embed"]["pos"].astype(x.dtype)[None, :s, :]
    enc_out = None
    if cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, frontend)
    elif cfg.family == "vlm":
        enc_out = frontend.astype(x.dtype)
    x, _, aux = _run_stack(
        cfg, params, x, positions=positions, enc_out=enc_out,
        moe_impl=moe_impl,
    )
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.lm_logits(cfg, params["head"], params["embed"], x)
    return logits, aux


def lm_loss(cfg: ArchConfig, params, batch, *, moe_impl="scatter",
            aux_weight=0.01):
    """Next-token cross-entropy (+ MoE aux). batch: {tokens, labels,
    frontend?}. Optional ``cfg.loss_chunk`` computes the head+CE in
    sequence chunks to bound logits memory."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    x = layers.embed(cfg, params["embed"], tokens)
    b, s = tokens.shape
    positions = jnp.arange(s)
    if cfg.rope_theta == 0.0 and "pos_embed" in params:
        x = x + params["pos_embed"]["pos"].astype(x.dtype)[None, :s, :]
    enc_out = None
    if cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, batch["frontend"])
    elif cfg.family == "vlm":
        enc_out = batch["frontend"].astype(x.dtype)
    x, _, aux = _run_stack(
        cfg, params, x, positions=positions, enc_out=enc_out, moe_impl=moe_impl
    )
    x = layers.apply_norm(cfg, params["ln_f"], x)

    def ce(xc, yc):
        logits = layers.lm_logits(cfg, params["head"], params["embed"], xc)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    if cfg.loss_chunk and s % cfg.loss_chunk == 0 and s > cfg.loss_chunk:
        nc = s // cfg.loss_chunk
        xc = x.reshape(b, nc, cfg.loss_chunk, -1).transpose(1, 0, 2, 3)
        yc = labels.reshape(b, nc, cfg.loss_chunk).transpose(1, 0, 2)

        def body(tot, inp):
            xi, yi = inp
            return tot + ce(xi, yi), None

        total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, yc))
    else:
        total = ce(x, labels)
    loss = total / (b * s)
    return loss + aux_weight * aux


# ---------------------------------------------------------------- serving
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None):
    """Allocate the decode cache pytree (list per pattern pos, stacked over
    groups)."""
    dtype = dtype or layers.cdtype(cfg)
    pattern = layer_pattern(cfg)
    ng = n_groups(cfg)
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    caches = []
    for desc in pattern:
        if desc.mixer == "attn":
            c = {
                "attn": {
                    "k": jnp.zeros((ng, batch, max_seq, kv, hd), dtype),
                    "v": jnp.zeros((ng, batch, max_seq, kv, hd), dtype),
                }
            }
        else:
            c = {
                "mamba": {
                    "conv": jnp.zeros(
                        (ng, batch, cfg.ssm_conv - 1, mamba2.conv_dim(cfg)),
                        dtype,
                    ),
                    "ssm": jnp.zeros(
                        (
                            ng,
                            batch,
                            cfg.ssm_heads,
                            cfg.ssm_headdim,
                            cfg.ssm_state,
                        ),
                        dtype,
                    ),
                }
            }
        caches.append(c)
    return caches


def prefill(cfg: ArchConfig, params, tokens, *, max_seq, frontend=None,
            moe_impl="scatter"):
    """Process the prompt, return (last-token logits, cache)."""
    b, s = tokens.shape
    x = layers.embed(cfg, params["embed"], tokens)
    positions = jnp.arange(s)
    if cfg.rope_theta == 0.0 and "pos_embed" in params:
        x = x + params["pos_embed"]["pos"].astype(x.dtype)[None, :s, :]
    enc_out = None
    if cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, frontend)
    elif cfg.family == "vlm":
        enc_out = frontend.astype(x.dtype)

    caches = init_cache(cfg, b, max_seq)
    x, new_caches, _ = _run_stack(
        cfg, params, x, positions=positions, enc_out=enc_out,
        caches=caches, cache_pos=0, moe_impl=moe_impl,
    )
    x = layers.apply_norm(cfg, params["ln_f"], x[:, -1:, :])
    logits = layers.lm_logits(cfg, params["head"], params["embed"], x)
    return logits[:, 0, :], new_caches


def decode_step(cfg: ArchConfig, params, token, caches, pos, *,
                enc_out=None, moe_impl="scatter"):
    """One decode step. token: (B, 1); pos: scalar int32 (current length).
    Returns (logits (B, V), new_caches)."""
    x = layers.embed(cfg, params["embed"], token)
    if enc_out is not None:
        enc_out = enc_out.astype(layers.cdtype(cfg))
    positions = jnp.reshape(pos, (1,))
    if cfg.rope_theta == 0.0 and "pos_embed" in params:
        pe = jax.lax.dynamic_slice_in_dim(
            params["pos_embed"]["pos"], pos, 1, 0
        )
        x = x + pe.astype(x.dtype)[None]
    x, new_caches, _ = _run_stack(
        cfg, params, x, positions=positions, enc_out=enc_out,
        caches=caches, cache_pos=pos, moe_impl=moe_impl,
    )
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.lm_logits(cfg, params["head"], params["embed"], x)
    return logits[:, 0, :], new_caches
