"""Transformer building blocks: norms, RoPE, GQA attention (+cross-attn),
MLP variants. Pure-functional: params are plain dict pytrees; every layer
has an ``init_*`` (allocating) and an ``apply``-style function.

Conventions:
  activations  (B, S, D) in cfg.compute_dtype (bf16 by default)
  params       fp32 (cast to compute dtype at use — mixed precision)
  attention weights  wq (D, H, hd) / wk,wv (D, KV, hd) / wo (H, hd, D)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def _init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / max(fan_in, 1) ** 0.5
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


# ---------------------------------------------------------------- norms
def init_norm(cfg: ArchConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(cfg: ArchConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def _rms_head(x, scale, eps):
    """Per-head-dim RMS norm for qk_norm (fp32 accumulate)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, hd/2)
        ang = ang[None, :, None, :]  # (1, S, 1, hd/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def init_attention(cfg: ArchConfig, key, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h, hd)),
        "wk": _init(ks[1], (d, kv, hd)),
        "wv": _init(ks[2], (d, kv, hd)),
        "wo": _init(ks[3], (h, hd, d), scale=1.0 / (h * hd) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(cfg, p, x, kv_x):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg, q, k, v, causal: bool, q_offset=0):
    """q: (B, Sq, H, hd); k,v: (B, Sk, KV, hd). GQA via head grouping.
    Softmax in fp32. Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    group = h // max(kv, 1)
    qg = q.reshape(b, sq, kv, group, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if causal:
        qpos = q_offset + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(cfg, q, k, v, causal: bool, q_offset=0, chunk: int = 1024):
    """Flash-style attention: scan over KV chunks with running max/sum —
    never materializes the (Sq, Sk) score matrix in HBM. Numerically equal
    to _sdpa (fp32 softmax accumulation). Used when cfg.attn_impl ==
    'chunked'; the §Perf memory-term optimization for prefill_32k."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if sk % chunk != 0:
        return _sdpa(cfg, q, k, v, causal, q_offset)
    group = h // max(kv, 1)
    qg = q.reshape(b, sq, kv, group, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    nchunks = sk // chunk
    kc = k.reshape(b, nchunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_run, l_run, acc = carry
        kci, vci, idx = inp
        logits = (
            jnp.einsum("bqkgh,bskh->bkgqs", qg, kci).astype(jnp.float32)
            * scale
        )
        if cfg.attn_logit_softcap > 0:
            c = cfg.attn_logit_softcap
            logits = c * jnp.tanh(logits / c)
        if causal:
            kpos = idx * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p_ = jnp.exp(logits - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p_, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p_.astype(q.dtype), vci
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, kv, group, sq, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(nchunks))
    )
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def attention(cfg, p, x, *, positions, causal=True, kv_x=None,
              cache=None, cache_pos=None):
    """Self- or cross-attention.

    cache: optional dict {k: (B, S_max, KV, hd), v: ...}. For decode, the
    new k/v are written at ``cache_pos`` and attention runs over the full
    cache buffer (positions >= written length are masked by causality).
    Returns (out, new_cache).
    """
    kv_src = x if kv_x is None else kv_x
    q, k, v = _project_qkv(cfg, p, x, kv_src)
    use_rope = cfg.rope_theta > 0 and kv_x is None
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    sdpa = (
        (lambda *a, **kw: _sdpa_chunked(*a, **kw, chunk=cfg.attn_chunk))
        if cfg.attn_impl == "chunked"
        else _sdpa
    )
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, 1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        out = sdpa(cfg, q, k, v, causal, q_offset=cache_pos)
    else:
        out = sdpa(cfg, q, k, v, causal)
    dt = x.dtype
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------- MLPs
def init_mlp(cfg: ArchConfig, key, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": _init(ks[0], (d, f)),
            "w_up": _init(ks[1], (d, f)),
            "w_down": _init(ks[2], (f, d)),
        }
    return {  # squared_relu | gelu: single up projection
        "w_up": _init(ks[0], (d, f)),
        "w_down": _init(ks[1], (f, d)),
    }


def apply_mlp(cfg: ArchConfig, p, x):
    dt = x.dtype
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        if cfg.mlp == "squared_relu":
            h = jnp.square(jax.nn.relu(u))
        else:
            h = jax.nn.gelu(u)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------- embeddings
def init_embedding(cfg: ArchConfig, key):
    return {"table": _init(key, (cfg.vocab_padded, cfg.d_model), scale=0.02)}


def embed(cfg, p, tokens):
    return p["table"].astype(cdtype(cfg))[tokens]


def init_lm_head(cfg: ArchConfig, key):
    if cfg.tie_embeddings:
        return {}
    return {"w": _init(key, (cfg.d_model, cfg.vocab_padded))}


def lm_logits(cfg, head_p, embed_p, x):
    dt = x.dtype
    if cfg.tie_embeddings:
        w = embed_p["table"].astype(dt).T
    else:
        w = head_p["w"].astype(dt)
    return jnp.einsum("bsd,dv->bsv", x, w)


def init_pos_embedding(cfg: ArchConfig, key, max_len: int):
    """Learned absolute positions (whisper-style, used when rope_theta==0)."""
    return {"pos": _init(key, (max_len, cfg.d_model), scale=0.02)}
