"""Incremental moment store: mergeable sufficient statistics.

:class:`MomentState` holds the (count, mean, centered second moment)
of a set of sample rows as a registered pytree, closed under two
operations:

  * ``merge(a, b)`` — Chan et al.'s pairwise update: the state of the
    union of two disjoint row sets, from their states alone. Numerically
    safe where the one-pass ``E[x^2] - mu^2`` form cancels (the same
    fp32 discipline as the two-pass ``step_standardize``): the second
    moments stay *centered* end to end, and the cross term enters as a
    rank-1 ``outer(delta, delta)`` correction.
  * ``retract(s, b)`` — the exact algebraic inverse of ``merge``: the
    state of ``s``'s rows minus ``b``'s. A rolling window advances by
    absorbing the new chunk and retracting the expired one, O(chunk d^2)
    per slide instead of an O(window d^2) rescan.

``update_chunk`` / ``retract_chunk`` wrap the two with a direct
two-pass summary of the raw rows (:func:`from_chunk`). All five are
jitted; the state flows through ``jit``/``vmap`` freely.

Retraction is subtraction, so it cancels: each retired chunk removes
mass of the same magnitude it added, and fp32 error accumulates with
the *stream length*, not the window length. It is numerically safe
while the window mean drifts slowly relative to the column scales
(stationary or slowly-varying series); for adversarial drift, re-anchor
periodically by rebuilding the state from the live chunks
(``RollingVarLiNGAM(reanchor_every=...)`` does exactly this).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class MomentState:
    """Sufficient statistics of ``count`` sample rows in R^d.

    ``m2`` is the *centered* second-moment sum
    ``sum_t (x_t - mean)(x_t - mean)^T`` — divide by ``count`` for the
    ddof=0 covariance. ``count`` is carried as f32 so the state is a
    uniform pytree under ``vmap``/``scan``.
    """

    count: jax.Array  # ()     f32 — number of absorbed rows
    mean: jax.Array   # (d,)   f32
    m2: jax.Array     # (d, d) f32 — centered second-moment sums

    def merge(self, other: "MomentState") -> "MomentState":
        return merge(self, other)

    def update_chunk(self, rows) -> "MomentState":
        return update_chunk(self, rows)

    def retract_chunk(self, rows) -> "MomentState":
        return retract_chunk(self, rows)

    @property
    def covariance(self):
        return covariance(self)


jax.tree_util.register_dataclass(
    MomentState,
    data_fields=["count", "mean", "m2"],
    meta_fields=[],
)


def init(d: int) -> MomentState:
    """Empty state over d variables (identity of ``merge``)."""
    return MomentState(
        count=jnp.float32(0.0),
        mean=jnp.zeros((d,), jnp.float32),
        m2=jnp.zeros((d, d), jnp.float32),
    )


@jax.jit
def from_chunk(rows) -> MomentState:
    """Direct two-pass summary of (n, d) raw rows.

    This is the ground-truth computation the merge/retract algebra must
    round-trip to (the property tests pin it): mean first, then centered
    outer products — never ``E[x^2] - mu^2``.
    """
    rows = rows.astype(jnp.float32)
    n = rows.shape[0]
    mu = jnp.mean(rows, axis=0)
    xc = rows - mu[None, :]
    return MomentState(count=jnp.float32(n), mean=mu, m2=xc.T @ xc)


@jax.jit
def merge(a: MomentState, b: MomentState) -> MomentState:
    """Chan-style pairwise merge of two disjoint row sets' states.

    Commutative and associative up to fp32 rounding; ``init(d)`` is the
    identity. Safe when either side is empty.
    """
    n = a.count + b.count
    n_safe = jnp.maximum(n, 1.0)
    delta = b.mean - a.mean
    mean = a.mean + delta * (b.count / n_safe)
    m2 = a.m2 + b.m2 + jnp.outer(delta, delta) * (a.count * b.count / n_safe)
    return MomentState(count=n, mean=mean, m2=m2)


@jax.jit
def retract(s: MomentState, b: MomentState) -> MomentState:
    """Inverse merge: the state of ``s``'s rows with ``b``'s removed.

    Exact algebraic inverse of ``merge(a, b) -> s`` solved for ``a``;
    requires ``b``'s rows to be a subset of the mass in ``s``
    (``b.count <= s.count``). Retracting everything returns a zeroed
    state (guarded divisions).
    """
    na = s.count - b.count
    na_safe = jnp.maximum(na, 1.0)
    mean_a = (s.count * s.mean - b.count * b.mean) / na_safe
    delta = b.mean - mean_a
    m2 = s.m2 - b.m2 - jnp.outer(delta, delta) * (
        na * b.count / jnp.maximum(s.count, 1.0)
    )
    empty = na <= 0.0
    return MomentState(
        count=jnp.maximum(na, 0.0),
        mean=jnp.where(empty, 0.0, mean_a),
        m2=jnp.where(empty, 0.0, m2),
    )


def update_chunk(s: MomentState, rows) -> MomentState:
    """Absorb (n, d) raw rows: ``merge(s, from_chunk(rows))``."""
    return merge(s, from_chunk(jnp.asarray(rows)))


def retract_chunk(s: MomentState, rows) -> MomentState:
    """Remove previously absorbed (n, d) raw rows from the state."""
    return retract(s, from_chunk(jnp.asarray(rows)))


def covariance(s: MomentState):
    """(d, d) ddof=0 covariance of the absorbed rows."""
    return s.m2 / jnp.maximum(s.count, 1.0)


def variance(s: MomentState):
    """(d,) ddof=0 per-column variances."""
    return jnp.diagonal(covariance(s))


def correlation(s: MomentState, eps: float = 1e-12):
    """(d, d) correlation derived from the covariance."""
    cov = covariance(s)
    sd = jnp.maximum(jnp.sqrt(jnp.maximum(jnp.diagonal(cov), 0.0)), eps)
    return cov / (sd[:, None] * sd[None, :])
