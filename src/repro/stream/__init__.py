"""Streaming causal discovery: incremental moments, rolling windows,
serving sessions.

  * :mod:`repro.stream.stats` — mergeable sufficient statistics
    (:class:`MomentState`: Chan-style merge + exact retraction).
  * :mod:`repro.stream.window` — :class:`ChunkRing` +
    :class:`RollingVarLiNGAM`: a VarLiNGAM whose window advances by
    absorbing/retracting chunks instead of rescanning.
  * :mod:`repro.stream.session` — :class:`StreamSession` /
    :class:`GraphDelta`: the per-client state the serving engine
    admits and batch-refits.
  * :mod:`repro.stream.monitor` — :class:`GraphHealthMonitor` /
    :class:`DriftAlert`: sequential drift tests on the served graph's
    structural noise, computed purely from chunk moment summaries.
"""

from .monitor import (  # noqa: F401
    DriftAlert,
    GraphHealthMonitor,
    MonitorConfig,
    score_chunks_many,
)
from .session import (  # noqa: F401
    GraphDelta,
    StreamConfig,
    StreamSession,
    graph_delta,
)
from .stats import MomentState  # noqa: F401
from .stats import (  # noqa: F401
    from_chunk,
    init,
    merge,
    retract,
    retract_chunk,
    update_chunk,
)
from .window import (  # noqa: F401
    ChunkRing,
    RollingFit,
    RollingVarLiNGAM,
    direct_window_fit,
    lagged_rows,
)
