"""Streaming sessions: per-client rolling state + graph deltas.

A :class:`StreamSession` is the serving-side wrapper around one
:class:`~repro.stream.window.RollingVarLiNGAM`: clients post (chunk, d)
row blocks, the session tracks when a refit is *due* (window full and
``refit_every`` chunks absorbed since the last estimate), and each
completed refit is summarized as a :class:`GraphDelta` against the
session's previous adjacency — the increment a subscriber actually
wants, not the full (d, d) matrix every slide.

Sessions do not execute refits themselves: the engine
(:class:`repro.serve.engine.CausalDiscoveryEngine`) collects due
sessions, groups their :class:`~repro.stream.window.RefitPlan`s by
(shape, fit-config) bucket, and runs each bucket through the batched
``fit_many_from_stats`` path — one device-parallel program per burst of
due windows. ``StreamSession.refit_now`` keeps a direct single-session
path for library use.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import api
from repro.obs import metrics as obs_metrics
from . import window as window_lib


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static shape/cadence knobs of one streaming session.

    ``chunk`` rows arrive per post; ``window_chunks`` chunks form the
    rolling window; a refit is due every ``refit_every`` chunks once the
    window is full. ``delta_threshold`` binarizes adjacencies for the
    edge add/remove sets. ``reanchor_every`` (slides) caps moment-
    retraction drift on non-stationary streams (0 = never; see
    :mod:`repro.stream.stats` for when that is safe to leave off).
    """

    d: int
    chunk: int
    window_chunks: int
    lags: int = 1
    refit_every: int = 1
    delta_threshold: float = 0.05
    reanchor_every: int = 0
    fit: api.FitConfig = api.FitConfig(compaction="staged")


@dataclasses.dataclass
class GraphDelta:
    """One refit's change against the session's previous estimate."""

    refit_index: int            # 0 for the first estimate of a session
    n_edges: int                # |{(i, j): |B0_ij| > threshold}| now
    added: np.ndarray           # (a, 2) int (i, j) edges newly above
    removed: np.ndarray         # (r, 2) int edges newly below
    max_abs_change: float       # max |B0_new - B0_prev| (0.0 on first)
    frob_change: float          # ||B0_new - B0_prev||_F (0.0 on first)

    def summary(self) -> str:
        return (
            f"refit {self.refit_index}: edges={self.n_edges} "
            f"+{len(self.added)}/-{len(self.removed)} "
            f"max|dB|={self.max_abs_change:.4f} "
            f"frob(dB)={self.frob_change:.4f}"
        )


def graph_delta(
    prev: Optional[np.ndarray],
    new: np.ndarray,
    threshold: float,
    refit_index: int,
) -> GraphDelta:
    """Edge-set and magnitude delta between two adjacency estimates."""
    new = np.asarray(new)
    mask_new = np.abs(new) > threshold
    if prev is None:
        return GraphDelta(
            refit_index=refit_index,
            n_edges=int(mask_new.sum()),
            added=np.argwhere(mask_new),
            removed=np.zeros((0, 2), dtype=np.int64),
            max_abs_change=0.0,
            frob_change=0.0,
        )
    prev = np.asarray(prev)
    mask_prev = np.abs(prev) > threshold
    diff = new - prev
    return GraphDelta(
        refit_index=refit_index,
        n_edges=int(mask_new.sum()),
        added=np.argwhere(mask_new & ~mask_prev),
        removed=np.argwhere(mask_prev & ~mask_new),
        max_abs_change=float(np.abs(diff).max()),
        frob_change=float(np.linalg.norm(diff)),
    )


class StreamSession:
    """One client's rolling discovery state inside the engine."""

    def __init__(self, sid: str, config: StreamConfig):
        self.sid = sid
        self.config = config
        self.rolling = window_lib.RollingVarLiNGAM(
            config.d,
            config.chunk,
            config.window_chunks,
            lags=config.lags,
            config=config.fit,
            reanchor_every=config.reanchor_every,
        )
        self._chunks_since_refit = 0
        self.n_refits = 0
        self.last_fit: Optional[window_lib.RollingFit] = None
        self.last_delta: Optional[GraphDelta] = None
        self._prev_adjacency: Optional[np.ndarray] = None
        # Monotonic timestamp of the post that made this session due
        # (None while not due) — the engine reads it at flush time to
        # report the refit queue wait. Tracked unconditionally: two
        # attribute writes per transition, no clock reads off-path.
        self._due_since: Optional[float] = None

    def post(self, rows) -> bool:
        """Absorb one chunk; returns True when a refit is now due."""
        self.rolling.push(rows)
        if self.rolling.ready:
            self._chunks_since_refit += 1
        obs_metrics.inc("stream.chunks", sid=self.sid)
        obs_metrics.gauge(
            "stream.staleness_chunks", self._chunks_since_refit,
            sid=self.sid,
        )
        if self.due and self._due_since is None:
            self._due_since = time.monotonic()
        return self.due

    def due_wait_s(self, now: Optional[float] = None) -> Optional[float]:
        """Seconds this session has been due without a refit (None when
        not due). ``now`` lets a flush sample one clock for a batch."""
        if self._due_since is None:
            return None
        return (time.monotonic() if now is None else now) - self._due_since

    @property
    def due(self) -> bool:
        return (
            self.rolling.ready
            and self._chunks_since_refit >= self.config.refit_every
        )

    def apply_fit(self, fit: window_lib.RollingFit) -> GraphDelta:
        """Record a completed refit; returns the delta vs the previous
        estimate (thresholded at ``config.delta_threshold``)."""
        b0 = np.asarray(fit.result.adjacency)
        delta = graph_delta(
            self._prev_adjacency, b0, self.config.delta_threshold,
            self.n_refits,
        )
        self._prev_adjacency = b0
        self.last_fit = fit
        self.last_delta = delta
        self.n_refits += 1
        self._chunks_since_refit = 0
        self._due_since = None
        obs_metrics.inc("stream.refits", sid=self.sid)
        obs_metrics.gauge("stream.staleness_chunks", 0, sid=self.sid)
        return delta

    def refit_now(self) -> GraphDelta:
        """Single-session refit path (no engine batching)."""
        return self.apply_fit(self.rolling.refit())


def bucket_key(
    session: StreamSession, plan: window_lib.RefitPlan
) -> Tuple[Tuple[int, ...], api.FitConfig]:
    """Batched-execution bucket: identical residual shapes + identical
    (hashable) fit configs share one ``fit_many_from_stats`` program."""
    return tuple(plan.resid.shape), session.rolling.config


__all__: List[str] = [
    "GraphDelta",
    "StreamConfig",
    "StreamSession",
    "bucket_key",
    "graph_delta",
]
