"""Streaming sessions: per-client rolling state + graph deltas.

A :class:`StreamSession` is the serving-side wrapper around one
:class:`~repro.stream.window.RollingVarLiNGAM`: clients post (chunk, d)
row blocks, the session tracks when a refit is *due* (window full and
``refit_every`` chunks absorbed since the last estimate), and each
completed refit is summarized as a :class:`GraphDelta` against the
session's previous adjacency — the increment a subscriber actually
wants, not the full (d, d) matrix every slide.

Sessions do not execute refits themselves: the engine
(:class:`repro.serve.engine.CausalDiscoveryEngine`) collects due
sessions, groups their :class:`~repro.stream.window.RefitPlan`s by
(shape, fit-config) bucket, and runs each bucket through the batched
``fit_many_from_stats`` path — one device-parallel program per burst of
due windows. ``StreamSession.refit_now`` keeps a direct single-session
path for library use.

With a :class:`~repro.stream.monitor.MonitorConfig` attached, every
posted chunk's moment summary is also scored against the currently
served graph (:mod:`repro.stream.monitor` — no row re-reads), and the
refit cadence becomes *adaptive*: a :class:`DriftAlert` makes the
session due immediately, while alert-free refits whose graph barely
moved let the cadence coast (doubling up to ``coast_max``) so stable
streams stop paying for refits that change nothing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import api
from repro.obs import metrics as obs_metrics
from repro.obs.ring import BoundedRing
from . import monitor as monitor_lib
from . import window as window_lib


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static shape/cadence knobs of one streaming session.

    ``chunk`` rows arrive per post; ``window_chunks`` chunks form the
    rolling window; a refit is due every ``refit_every`` chunks once the
    window is full. ``delta_threshold`` binarizes adjacencies for the
    edge add/remove sets. ``reanchor_every`` (slides) caps moment-
    retraction drift on non-stationary streams (0 = never; see
    :mod:`repro.stream.stats` for when that is safe to leave off).

    ``monitor`` attaches a graph-health monitor to the session (None =
    no drift detection, fixed cadence). ``coast_max`` enables adaptive
    cadence: after an alert-free refit whose adjacency moved by at most
    ``delta_threshold``, the refit interval doubles, up to ``coast_max``
    chunks; any drift alert resets it to ``refit_every`` and makes the
    session due at once (0 = fixed cadence even when monitored).
    """

    d: int
    chunk: int
    window_chunks: int
    lags: int = 1
    refit_every: int = 1
    delta_threshold: float = 0.05
    reanchor_every: int = 0
    fit: api.FitConfig = api.FitConfig(compaction="staged")
    monitor: Optional[monitor_lib.MonitorConfig] = None
    coast_max: int = 0


@dataclasses.dataclass
class GraphDelta:
    """One refit's change against the session's previous estimate."""

    refit_index: int            # 0 for the first estimate of a session
    n_edges: int                # |{(i, j): |B0_ij| > threshold}| now
    added: np.ndarray           # (a, 2) int (i, j) edges newly above
    removed: np.ndarray         # (r, 2) int edges newly below
    max_abs_change: float       # max |B0_new - B0_prev| (0.0 on first)
    frob_change: float          # ||B0_new - B0_prev||_F (0.0 on first)
    drift_score: float = 0.0    # monitor level at refit time (1.0 = alarm)
    triggered_by: str = "cadence"   # "cadence" | "alert"
    alerts: List[monitor_lib.DriftAlert] = dataclasses.field(
        default_factory=list)    # the alerts that forced this refit

    def summary(self) -> str:
        base = (
            f"refit {self.refit_index}: edges={self.n_edges} "
            f"+{len(self.added)}/-{len(self.removed)} "
            f"max|dB|={self.max_abs_change:.4f} "
            f"frob(dB)={self.frob_change:.4f}"
        )
        if self.triggered_by == "alert" or self.drift_score > 0.0:
            kinds = ",".join(sorted({a.kind for a in self.alerts})) or "-"
            base += (
                f" drift={self.drift_score:.2f} by={self.triggered_by}"
                f"[{kinds}]"
            )
        return base


def graph_delta(
    prev: Optional[np.ndarray],
    new: np.ndarray,
    threshold: float,
    refit_index: int,
) -> GraphDelta:
    """Edge-set and magnitude delta between two adjacency estimates."""
    new = np.asarray(new)
    mask_new = np.abs(new) > threshold
    if prev is None:
        return GraphDelta(
            refit_index=refit_index,
            n_edges=int(mask_new.sum()),
            added=np.argwhere(mask_new),
            removed=np.zeros((0, 2), dtype=np.int64),
            max_abs_change=0.0,
            frob_change=0.0,
        )
    prev = np.asarray(prev)
    mask_prev = np.abs(prev) > threshold
    diff = new - prev
    return GraphDelta(
        refit_index=refit_index,
        n_edges=int(mask_new.sum()),
        added=np.argwhere(mask_new & ~mask_prev),
        removed=np.argwhere(mask_prev & ~mask_new),
        max_abs_change=float(np.abs(diff).max()),
        frob_change=float(np.linalg.norm(diff)),
    )


class StreamSession:
    """One client's rolling discovery state inside the engine."""

    def __init__(self, sid: str, config: StreamConfig):
        self.sid = sid
        self.config = config
        self.rolling = window_lib.RollingVarLiNGAM(
            config.d,
            config.chunk,
            config.window_chunks,
            lags=config.lags,
            config=config.fit,
            reanchor_every=config.reanchor_every,
        )
        self._chunks_since_refit = 0
        self.n_refits = 0
        self.n_chunks = 0
        self.last_fit: Optional[window_lib.RollingFit] = None
        self.last_delta: Optional[GraphDelta] = None
        self._prev_adjacency: Optional[np.ndarray] = None
        # Monotonic timestamp of the post that made this session due
        # (None while not due) — the engine reads it at flush time to
        # report the refit queue wait. Tracked unconditionally: two
        # attribute writes per transition, no clock reads off-path.
        self._due_since: Optional[float] = None
        # Adaptive cadence: current refit interval in chunks. Fixed at
        # refit_every unless coast_max > 0 (see apply_fit).
        self._cadence = config.refit_every
        mc = config.monitor
        self.monitor: Optional[monitor_lib.GraphHealthMonitor] = (
            monitor_lib.GraphHealthMonitor(mc, config.d, config.lags,
                                           sid=sid)
            if mc is not None else None
        )
        # pending: alerts that have not yet been answered by a refit
        # (drives `due`; drained into the triggering GraphDelta).
        # unread: alerts not yet collected through the engine's
        # poll_alerts API. history: everything, for post-hoc review.
        cap = mc.max_pending if mc else 1
        hist = mc.history if mc else 1
        self.pending_alerts: BoundedRing = BoundedRing(cap)
        self.unread_alerts: BoundedRing = BoundedRing(cap)
        self.alert_history: BoundedRing = BoundedRing(hist)

    def post(self, rows) -> bool:
        """Absorb one chunk; returns True when a refit is now due.

        The chunk's moment summary (already produced by the rolling
        window's slide — monitoring adds no data pass) is scored
        against the served graph when a monitor is armed; any fired
        alerts land in the session's alert rings and make it due.
        """
        chunk_state = self.rolling.push(rows)
        self.n_chunks += 1
        if self.rolling.ready:
            self._chunks_since_refit += 1
        if self.monitor is not None and self.monitor.armed:
            self.absorb_alerts(self.monitor.update(
                chunk_state,
                chunk_index=self.n_chunks,
                refit_index=self.n_refits,
            ))
        obs_metrics.inc("stream.chunks", sid=self.sid)
        obs_metrics.gauge(
            "stream.staleness_chunks", self._chunks_since_refit,
            sid=self.sid,
        )
        if self.due and self._due_since is None:
            self._due_since = time.monotonic()
        return self.due

    def absorb_alerts(self, alerts) -> None:
        """File fired alerts; an alert resets any coasting cadence."""
        for a in alerts:
            self.pending_alerts.append(a)
            self.unread_alerts.append(a)
            self.alert_history.append(a)
        if alerts:
            self._cadence = self.config.refit_every
            obs_metrics.gauge(
                "stream.cadence_chunks", self._cadence, sid=self.sid,
            )

    def due_wait_s(self, now: Optional[float] = None) -> Optional[float]:
        """Seconds this session has been due without a refit (None when
        not due). ``now`` lets a flush sample one clock for a batch."""
        if self._due_since is None:
            return None
        return (time.monotonic() if now is None else now) - self._due_since

    @property
    def cadence(self) -> int:
        """Current refit interval in chunks (adaptive when coasting)."""
        return self._cadence

    @property
    def due(self) -> bool:
        return self.rolling.ready and (
            bool(self.pending_alerts)
            or self._chunks_since_refit >= self._cadence
        )

    def apply_fit(self, fit: window_lib.RollingFit) -> GraphDelta:
        """Record a completed refit; returns the delta vs the previous
        estimate (thresholded at ``config.delta_threshold``).

        Closes out any pending drift alerts (they triggered this refit
        and travel on the delta), re-arms the monitor on the fresh
        estimate, and advances the adaptive cadence: alert-free refits
        whose adjacency moved by at most ``delta_threshold`` double the
        interval (up to ``coast_max``); anything else resets it.
        """
        triggered = list(self.pending_alerts.drain())
        drift_score = (
            self.monitor.max_score()
            if self.monitor is not None and self.monitor.armed else 0.0
        )
        b0 = np.asarray(fit.result.adjacency)
        delta = graph_delta(
            self._prev_adjacency, b0, self.config.delta_threshold,
            self.n_refits,
        )
        delta.drift_score = drift_score
        delta.triggered_by = "alert" if triggered else "cadence"
        delta.alerts = triggered
        self._prev_adjacency = b0
        self.last_fit = fit
        self.last_delta = delta
        self.n_refits += 1
        self._chunks_since_refit = 0
        self._due_since = None
        if self.monitor is not None:
            self.monitor.arm(fit)
        if self.config.coast_max > 0:
            # Stability judged by the monitor when there is one — its
            # drift level is calibrated to the served model, while raw
            # adjacency deltas fluctuate with estimation noise at any
            # cadence. Unmonitored sessions fall back to the delta.
            stable = not triggered and (
                drift_score < 0.5 if self.monitor is not None
                else delta.max_abs_change <= self.config.delta_threshold
            )
            self._cadence = (
                min(self._cadence * 2, self.config.coast_max) if stable
                else self.config.refit_every
            )
            obs_metrics.gauge(
                "stream.cadence_chunks", self._cadence, sid=self.sid,
            )
        obs_metrics.inc("stream.refits", sid=self.sid)
        obs_metrics.inc(
            "stream.refits_by_trigger", trigger=delta.triggered_by,
            sid=self.sid,
        )
        obs_metrics.gauge("stream.staleness_chunks", 0, sid=self.sid)
        return delta

    def refit_now(self) -> GraphDelta:
        """Single-session refit path (no engine batching)."""
        return self.apply_fit(self.rolling.refit())


def bucket_key(
    session: StreamSession, plan: window_lib.RefitPlan
) -> Tuple[Tuple[int, ...], api.FitConfig]:
    """Batched-execution bucket: identical residual shapes + identical
    (hashable) fit configs share one ``fit_many_from_stats`` program."""
    return tuple(plan.resid.shape), session.rolling.config


__all__: List[str] = [
    "GraphDelta",
    "StreamConfig",
    "StreamSession",
    "bucket_key",
    "graph_delta",
]
