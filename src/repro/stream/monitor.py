"""Graph-health monitoring: streaming drift detection on served graphs.

A rolling refit silently overwrites the served graph; the scenario that
makes streaming causal discovery valuable at scale (markets,
microservices, gene panels) is *detecting when the causal mechanism
itself changes*. This module watches exactly that signal: the
structural noise of the currently-served graph,

    ``e = (I - B0) r``,  ``r = y - c - A z``

with ``[y, z]`` a chunk's lag-augmented rows and ``(B0, A, c,
resid_var)`` the served VarLiNGAM estimate. Under the served model the
per-variable noises are zero-mean, variance ``resid_var``, and mutually
independent — three testable invariants, each broken by a different
kind of structural change:

  * **mean shift** of ``e_j`` — intercept / regression-weight drift
    moving residual means (CUSUM on the standardized chunk mean; alert
    kind ``"weight-shift"``);
  * **variance shift** of ``e_j`` — the noise mechanism re-scaled, or
    un-modeled weight change leaking into the residual (CUSUM on the
    likelihood-ratio-style standardized variance statistic; alert kind
    ``"noise-scale"``);
  * **cross-dependence** between ``e_j`` and the other noises — edges
    appeared/flipped that the served ``B0`` no longer removes (CUSUM on
    an LM-type score from the chunk's residual correlations; alert
    kind ``"edge-flip"``).

Everything is computed **purely from the chunk's**
:class:`~repro.stream.stats.MomentState` — the (count, mean, centered
M2) summary the rolling window already produces per slide — so
monitoring costs one small jitted transform per chunk and never
re-reads rows (``tests/test_monitor.py`` pins zero extra data passes).
The transform is one compiled program per ``(d, lags)`` shape shared
across every session, with a vmapped batch entry
(:func:`score_chunks_many`) whose micro-batch bucketing follows the
kernel dispatcher's tuned sample block
(:func:`repro.kernels.tune.dispatch`) like the RCA slabs do.

Alerts are :class:`DriftAlert` objects carrying the implicated
variable, the firing statistic, a kind label, and candidate root
variables ranked via :func:`repro.infer.rca.drift_root_candidates`
(drift scores live in the structural-noise frame — the same frame RCA
decomposes into — so propagation to descendants is already
deconvolved). :class:`repro.stream.session.StreamSession` consumes
them for adaptive refit cadence (refit early on alert, coast while
stable) and :class:`repro.serve.engine.CausalDiscoveryEngine` surfaces
them through ``poll_alerts`` / flush deltas and ``obs.metrics``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics

from . import stats as stats_lib
from . import window as window_lib

_EPS = 1e-12

# Statistic index -> the structural-change kind it evidences.
STAT_KINDS = ("weight-shift", "noise-scale", "edge-flip")


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Sequential-test knobs of one graph-health monitor.

    The per-chunk statistics are standardized to ~unit scale under the
    served model, then accumulated in per-variable CUSUMs:
    ``S <- max(0, S + z - slack)``, alerting when ``S > threshold``.
    ``slack`` absorbs steady model error (the served graph is itself an
    estimate); ``threshold`` sets the false-alarm / detection-delay
    trade-off (~``threshold / (|z| - slack)`` chunks to detect a shift
    of size ``z``). ``var_slack`` adds slack to the variance statistic,
    whose null spread is widest under heavy-tailed (LiNGAM) noise —
    fourth moments are not in the moment state, so it cannot be
    kurtosis-corrected exactly.
    """

    slack: float = 1.0
    threshold: float = 14.0
    var_slack: float = 1.0      # extra slack for the variance statistic
    dep_slack: float = 0.5      # extra slack for the dependence statistic
    min_count: int = 8          # skip chunks with fewer effective rows
    max_pending: int = 64       # bounded per-session pending-alert ring
    history: int = 256          # bounded per-session alert history ring
    rca_top_k: int = 3          # candidate roots attached per alert
    cooldown: int = 2           # chunks between repeat alerts of one
    #                             (variable, kind) while the same drift
    #                             episode keeps accumulating


@dataclasses.dataclass
class DriftAlert:
    """One sequential test crossing its alarm level."""

    sid: str                 # owning stream session ("" for library use)
    variable: int            # variable whose invariant broke
    kind: str                # "weight-shift" | "noise-scale" | "edge-flip"
    score: float             # CUSUM level / threshold (>= 1.0 at fire)
    stat: float              # the chunk statistic that tipped it
    chunk_index: int         # session chunk count when it fired
    refit_index: int         # refits completed when it fired
    candidate_roots: List[Tuple[int, float]]  # [(variable, drift score)]
    #                          ranked via infer.rca.drift_root_candidates

    def summary(self) -> str:
        roots = ", ".join(f"x{v}:{s:.1f}" for v, s in self.candidate_roots)
        return (
            f"drift[{self.kind}] x{self.variable} score={self.score:.2f} "
            f"stat={self.stat:+.2f} chunk={self.chunk_index} "
            f"roots=[{roots}]"
        )


@jax.jit
def chunk_drift_stats(count, mean, m2, a, intercept, b0, resid_var):
    """Per-variable standardized drift statistics of one chunk, from
    its augmented :class:`MomentState` leaves alone.

    Args:
      count/mean/m2: the chunk's augmented moment summary — mean is
        ``((k+1)d,)``, m2 the centered ``((k+1)d, (k+1)d)`` sums.
      a:         (d, k d) served VAR coefficients.
      intercept: (d,) served VAR intercept.
      b0:        (d, d) served instantaneous adjacency.
      resid_var: (d,) served structural-noise variances.

    Returns ``(z_mean, z_var, z_dep)``, each ``(d,)``:
      * ``z_mean`` — chunk mean of ``e_j`` over its served standard
        error ``sqrt(resid_var_j / n)`` (~N(0,1) under the model);
      * ``z_var``  — ``(vhat_j / resid_var_j - 1) * sqrt(n / 2)``, the
        standardized Gaussian likelihood-ratio direction for a variance
        change (``vhat`` is the chunk's second moment of ``e_j`` about
        the model's zero mean, so un-modeled mean shifts surface here
        too);
      * ``z_dep``  — LM-type dependence score: mean over partners of
        ``n * corr(e_j, e_i)^2`` (each ~chi^2(1) under independence),
        centered and scaled to ~unit variance.

    The noise moments come from the linear maps ``r = y - c - A z``,
    ``e = (I - B0) r`` applied to the chunk's mean/covariance — exact,
    no row access.
    """
    from repro.obs import compile_log

    compile_log.record("monitor.chunk_drift_stats", shape=b0.shape)
    d = b0.shape[0]
    n = jnp.maximum(count, 1.0)
    cov_u = m2 / n
    mean_r = mean[:d] - a @ mean[d:] - intercept
    czy = cov_u[d:, :d]
    cov_r = (
        cov_u[:d, :d] - a @ czy - czy.T @ a.T + a @ cov_u[d:, d:] @ a.T
    )
    r0 = jnp.eye(d, dtype=b0.dtype) - b0
    mean_e = r0 @ mean_r
    cov_e = r0 @ cov_r @ r0.T
    v0 = jnp.maximum(resid_var, _EPS)

    z_mean = mean_e * jnp.sqrt(n / v0)
    vhat = jnp.maximum(jnp.diagonal(cov_e), 0.0) + mean_e**2
    z_var = (vhat / v0 - 1.0) * jnp.sqrt(n / 2.0)

    sd = jnp.sqrt(jnp.maximum(jnp.diagonal(cov_e), _EPS))
    corr = cov_e / (sd[:, None] * sd[None, :])
    corr = corr - jnp.diag(jnp.diagonal(corr))
    n_partners = jnp.maximum(d - 1, 1)
    dep = n * jnp.sum(corr**2, axis=1) / n_partners
    z_dep = (dep - 1.0) * jnp.sqrt(n_partners / 2.0)
    return z_mean, z_var, z_dep


_chunk_drift_stats_many = jax.jit(
    jax.vmap(chunk_drift_stats, in_axes=(0, 0, 0, 0, 0, 0, 0))
)


@dataclasses.dataclass
class ServedGraph:
    """The monitor's frozen view of the estimate it scores against."""

    a: np.ndarray          # (d, k d) VAR coefficients
    intercept: np.ndarray  # (d,)
    b0: np.ndarray         # (d, d) instantaneous adjacency
    order: np.ndarray      # (d,) causal order (for RCA ranking)
    resid_var: np.ndarray  # (d,)

    @classmethod
    def from_fit(cls, fit: window_lib.RollingFit) -> "ServedGraph":
        mats = np.asarray(fit.var_coefs)
        a = np.concatenate(list(mats), axis=1)  # [k, d, d] -> (d, k d)
        if fit.intercept is None:
            raise ValueError(
                "RollingFit.intercept missing — refit through "
                "finish_refit to monitor this graph"
            )
        return cls(
            a=a.astype(np.float32),
            intercept=np.asarray(fit.intercept, np.float32),
            b0=np.asarray(fit.result.adjacency, np.float32),
            order=np.asarray(fit.result.order),
            resid_var=np.asarray(fit.result.resid_var, np.float32),
        )


class GraphHealthMonitor:
    """Per-session sequential tests on a served graph's noise residuals.

    Lifecycle: :meth:`arm` freezes the served estimate and zeroes the
    CUSUM banks; :meth:`update` scores one chunk's
    :class:`MomentState` and returns any :class:`DriftAlert`\\ s that
    fired. ``max_score`` summarizes the current drift level (max CUSUM
    over variables and statistics, normalized by the threshold — 1.0
    means "at the alarm level"), which the session stamps into its
    :class:`~repro.stream.session.GraphDelta`.
    """

    def __init__(self, config: MonitorConfig, d: int, lags: int,
                 sid: str = ""):
        self.config = config
        self.d = d
        self.lags = lags
        self.sid = sid
        self.graph: Optional[ServedGraph] = None
        self.n_scored = 0
        # CUSUM banks, (3, d): mean/var two-sided kept as (pos, neg).
        self._pos = np.zeros((3, d), np.float32)
        self._neg = np.zeros((3, d), np.float32)
        self._last_alert: Dict[Tuple[int, str], int] = {}

    @property
    def armed(self) -> bool:
        return self.graph is not None

    def arm(self, fit: window_lib.RollingFit) -> None:
        """Adopt a freshly served estimate; restart the tests."""
        self.graph = ServedGraph.from_fit(fit)
        self._pos[:] = 0.0
        self._neg[:] = 0.0
        self._last_alert.clear()

    def max_score(self) -> float:
        """Current drift level: max CUSUM / threshold (1.0 = alarm)."""
        if self.graph is None:
            return 0.0
        peak = max(float(self._pos.max()), float(self._neg.max()))
        return peak / self.config.threshold

    def variable_scores(self) -> np.ndarray:
        """(d,) per-variable drift level (max over statistics / sides,
        normalized by the threshold) — the structural-noise-frame score
        vector RCA ranks root candidates from."""
        return (
            np.maximum(self._pos, self._neg).max(axis=0)
            / self.config.threshold
        )

    def _slacks(self) -> np.ndarray:
        c = self.config
        return np.array(
            [c.slack, c.slack + c.var_slack, c.slack + c.dep_slack],
            np.float32,
        )

    def update(
        self,
        chunk_state: stats_lib.MomentState,
        *,
        chunk_index: int = 0,
        refit_index: int = 0,
        zs: Optional[Sequence[np.ndarray]] = None,
    ) -> List[DriftAlert]:
        """Score one chunk's moment summary; returns fired alerts.

        ``zs`` lets a batched caller (:func:`score_chunks_many`) hand
        in precomputed statistics; otherwise the shared jitted
        transform runs on this chunk alone.
        """
        if self.graph is None:
            raise RuntimeError("monitor not armed — no served graph yet")
        if float(chunk_state.count) < self.config.min_count:
            return []
        if zs is None:
            g = self.graph
            zs = chunk_drift_stats(
                chunk_state.count, chunk_state.mean, chunk_state.m2,
                jnp.asarray(g.a), jnp.asarray(g.intercept),
                jnp.asarray(g.b0), jnp.asarray(g.resid_var),
            )
        z = np.stack([np.asarray(v, np.float32) for v in zs])  # (3, d)
        slack = self._slacks()[:, None]
        self._pos = np.maximum(0.0, self._pos + z - slack)
        # Negative side only where a drop is meaningful: means can
        # shift down, variances can collapse; the dependence score is
        # one-sided (independence cannot get "more true" than true).
        self._neg[:2] = np.maximum(0.0, self._neg[:2] - z[:2] - slack[:2])
        self.n_scored += 1

        alerts: List[DriftAlert] = []
        level = np.maximum(self._pos, self._neg)
        h = self.config.threshold
        for s_idx, kind in enumerate(STAT_KINDS):
            for j in np.nonzero(level[s_idx] > h)[0]:
                key = (int(j), kind)
                last = self._last_alert.get(key)
                if last is not None and (
                    chunk_index - last
                ) <= self.config.cooldown:
                    continue
                self._last_alert[key] = chunk_index
                alerts.append(self._alert(
                    int(j), kind, float(level[s_idx, j] / h),
                    float(z[s_idx, j]), chunk_index, refit_index,
                ))
        if alerts:
            obs_metrics.inc(
                "monitor.alerts", len(alerts), sid=self.sid or "-",
            )
        obs_metrics.gauge(
            "monitor.drift_score", self.max_score(), sid=self.sid or "-",
        )
        return alerts

    def _alert(self, variable, kind, score, stat, chunk_index,
               refit_index) -> DriftAlert:
        from repro.infer import rca

        cands = rca.drift_root_candidates(
            self.graph.b0, self.graph.order, self.variable_scores(),
            top_k=self.config.rca_top_k,
        )
        obs_metrics.inc(
            "monitor.alerts_by_kind", kind=kind, sid=self.sid or "-",
        )
        return DriftAlert(
            sid=self.sid, variable=variable, kind=kind, score=score,
            stat=stat, chunk_index=chunk_index, refit_index=refit_index,
            candidate_roots=cands,
        )


def _batch_bucket(n: int, d: int) -> int:
    """Micro-batch bucket for the vmapped scorer: the dispatcher's
    tuned sample block for this shape family bounds the padded batch
    (the same measured decision point the RCA slabs consult), rounded
    to the power-of-two set so steady traffic compiles O(log) shapes."""
    from repro.core.batched import pow2_bucket
    from repro.kernels import tune as ktune

    plan = ktune.dispatch(
        "pairwise_moment_sums_chunked", (n, d), mode="cache", chunk=n
    )
    cap = int(plan.bm) if plan.bm else max(n, 1)
    return pow2_bucket(n, max(cap, n, 1))


def score_chunks_many(
    monitors: Sequence[GraphHealthMonitor],
    chunk_states: Sequence[stats_lib.MomentState],
    *,
    chunk_indices: Optional[Sequence[int]] = None,
) -> List[List[DriftAlert]]:
    """Score one chunk per monitor as a single padded vmapped program.

    All monitors must share ``(d, lags)`` (one compile per shape
    family; the engine groups sessions the same way it buckets refits).
    Padding repeats the first entry up to the dispatcher-derived
    power-of-two bucket, so a burst of concurrent sessions costs one
    device program instead of a per-session loop.
    """
    if not monitors:
        return []
    n = len(monitors)
    bucket = _batch_bucket(n, monitors[0].d)
    pad = bucket - n

    def stack(xs):
        xs = list(xs) + [xs[0]] * pad
        return jnp.stack([jnp.asarray(x) for x in xs])

    graphs = [m.graph for m in monitors]
    if any(g is None for g in graphs):
        raise RuntimeError("every monitor must be armed before batching")
    zs = _chunk_drift_stats_many(
        stack([s.count for s in chunk_states]),
        stack([s.mean for s in chunk_states]),
        stack([s.m2 for s in chunk_states]),
        stack([g.a for g in graphs]),
        stack([g.intercept for g in graphs]),
        stack([g.b0 for g in graphs]),
        stack([g.resid_var for g in graphs]),
    )
    z_mean, z_var, z_dep = (np.asarray(z) for z in zs)
    out: List[List[DriftAlert]] = []
    for i, (mon, state) in enumerate(zip(monitors, chunk_states)):
        idx = chunk_indices[i] if chunk_indices is not None else 0
        out.append(mon.update(
            state, chunk_index=idx,
            zs=(z_mean[i], z_var[i], z_dep[i]),
        ))
    return out


__all__ = [
    "DriftAlert",
    "GraphHealthMonitor",
    "MonitorConfig",
    "ServedGraph",
    "STAT_KINDS",
    "chunk_drift_stats",
    "score_chunks_many",
]
