"""Rolling-window VarLiNGAM over the incremental moment store.

The paper's §4.2 stock panels are time series: consecutive analysis
windows share almost all of their rows, yet a from-scratch refit pays
the full cost of the window every slide — the VAR least squares, the
covariance matmuls, the standardization passes. Here a window slides in
*chunks*:

  * :class:`ChunkRing` — fixed-capacity ring of (chunk, d) row blocks;
    pushing into a full ring evicts (and returns) the oldest block.
  * :class:`RollingVarLiNGAM` — maintains a :class:`~repro.stream.stats.
    MomentState` over the window's *lag-augmented* rows
    ``[x_t, x_{t-1}, ..., x_{t-k}]``: each slide absorbs the new
    chunk's augmented rows and retracts the expired one's
    (O(chunk d^2)), instead of rescanning the window. A refit then
    reads the data only where it must:

      - VAR(k) coefficients come from the merged covariance blocks
        (one (kd, kd) solve — no O(m (kd)^2) lstsq over the window);
      - VAR residuals are materialized chunk-by-chunk (one small GEMM
        per live block);
      - the DirectLiNGAM step runs through ``api.fit_from_stats`` with
        the residual mean/covariance derived from the same state, so
        standardization, pruning, and diagnostics skip their data
        passes; only the nonlinear ordering moments re-read the rows,
        chunk-bounded via ``FitConfig.moment_chunk``.

:func:`direct_window_fit` is the from-scratch oracle: the identical
estimator computed from a direct two-pass over the whole window (no
merges, no retractions). ``tests/test_stream.py`` pins rolling == direct
within fp32 tolerance; ``benchmarks/bench_stream.py`` records the
per-slide speedup against it and against the legacy lstsq path.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.obs import metrics as obs_metrics
from . import stats

_RIDGE = 1e-6


def _tuned_moment_chunk(
    d: int, chunk: int, window_chunks: int, config: api.FitConfig
) -> int:
    """Default ordering-moment slab for a rolling window: ask the
    dispatcher for this window's tuned sample block, bounded by the
    stream chunk (the session's declared memory budget). With an empty
    tuning table (or ``tune="off"``) this degrades to the stream chunk
    exactly — the legacy default."""
    from repro.kernels import tune as ktune

    plan = ktune.dispatch(
        "pairwise_moment_sums_chunked",
        (chunk * window_chunks, d),
        backend=config.backend,
        mode=config.tune,
        chunk=chunk,
    )
    return min(chunk, plan.bm) if plan.bm else chunk


def lagged_rows(buf: np.ndarray, lags: int) -> np.ndarray:
    """Lag-augmented rows of a contiguous (n, d) block.

    Row t (for t in [lags, n)) is ``[x_t, x_{t-1}, ..., x_{t-lags}]``,
    shape (n - lags, (lags + 1) d) — the first ``lags`` rows of ``buf``
    serve only as history. A chunk pushed with its predecessor's
    ``lags``-row tail therefore contributes exactly ``chunk`` augmented
    rows; the stream's very first chunk contributes ``chunk - lags``.
    """
    n = buf.shape[0]
    if n <= lags:
        raise ValueError(f"need more than lags={lags} rows, got {n}")
    return np.concatenate(
        [buf[lags - tau : n - tau] for tau in range(lags + 1)], axis=1
    )


class ChunkRing:
    """Fixed-capacity FIFO ring of (chunk, d) row blocks.

    ``push`` returns the evicted oldest block once the ring is full
    (None before that). Iteration runs oldest -> newest.
    """

    def __init__(self, capacity: int):
        if capacity < 2:
            raise ValueError(f"ring needs >= 2 chunks, got {capacity}")
        self.capacity = capacity
        self._blocks: deque = deque()

    def push(self, rows: np.ndarray) -> Optional[np.ndarray]:
        self._blocks.append(rows)
        if len(self._blocks) > self.capacity:
            return self._blocks.popleft()
        return None

    @property
    def full(self) -> bool:
        return len(self._blocks) == self.capacity

    def __len__(self) -> int:
        return len(self._blocks)

    def __iter__(self):
        return iter(self._blocks)


@functools.partial(jax.jit, static_argnames=("d", "lags"))
def _var_solve(count, mean, m2, *, d: int, lags: int):
    """VAR(k) + residual stats from augmented-row moments.

    The augmented covariance's blocks are the normal equations of the
    windowed regression y = x_t on z = [x_(t-1), ..., x_(t-k)] with
    intercept: A = Cov(y, z) Cov(z, z)^-1 (tiny ridge for safety),
    intercept = mean_y - A mean_z, Cov(resid) = Cov(y) - A Cov(z, y)
    (exact at the solution; the residual mean is 0 by construction).
    Returns (a, mats, intercept, resid_cov) with ``a`` the (d, k d)
    stacked coefficient rows and ``mats`` its [k, d, d] per-lag view.
    """
    cov = m2 / jnp.maximum(count, 1.0)
    szz = cov[d:, d:]
    szy = cov[d:, :d]
    ridge = _RIDGE * jnp.mean(jnp.diagonal(szz)) + 1e-30
    szz = szz + ridge * jnp.eye(szz.shape[0], dtype=szz.dtype)
    a = jnp.linalg.solve(szz, szy).T  # (d, k d)
    intercept = mean[:d] - a @ mean[d:]
    mats = a.reshape(d, lags, d).transpose(1, 0, 2)  # [k, d, d]
    resid_cov = cov[:d, :d] - a @ szy
    resid_cov = 0.5 * (resid_cov + resid_cov.T)
    return a, mats, intercept, resid_cov


@jax.jit
def _residual_block(aug, a, intercept):
    """VAR residuals of one augmented block: y - intercept - z A^T."""
    d = intercept.shape[0]
    y = aug[:, :d]
    z = aug[:, d:]
    return y - intercept[None, :] - z @ a.T


@dataclasses.dataclass
class RefitPlan:
    """One due refit, ready for (batched) execution: the window's VAR
    residuals plus the moment-derived statistics ``fit_from_stats`` /
    ``fit_many_from_stats`` consume."""

    resid: jax.Array       # (m_aug, d) window VAR residuals
    resid_mean: jax.Array  # (d,) zeros — exact with the intercept
    resid_cov: jax.Array   # (d, d) state-derived residual covariance
    mats: np.ndarray       # [k, d, d] VAR coefficient matrices
    intercept: np.ndarray  # (d,)


@dataclasses.dataclass
class RollingFit:
    """One window's estimate: the instantaneous fit + lagged thetas."""

    result: api.FitResult       # order/adjacency(B0)/resid_var
    thetas: List[np.ndarray]    # [theta_0 (= B0), theta_1, ..., theta_k]
    var_coefs: np.ndarray       # [k, d, d] raw VAR coefficients
    n_rows: int                 # augmented rows in the window
    intercept: Optional[np.ndarray] = None  # (d,) VAR intercept — the
    #                             served-graph parameter the drift
    #                             monitor needs to score new chunks


def finish_refit(plan: RefitPlan, result: api.FitResult) -> RollingFit:
    """Lagged-coefficient transform theta_tau = (I - B0) M_tau."""
    b0 = np.asarray(result.adjacency)
    eye = np.eye(b0.shape[0], dtype=b0.dtype)
    mats = np.asarray(plan.mats)
    thetas = [b0] + [
        np.asarray((eye - b0) @ mats[tau]) for tau in range(mats.shape[0])
    ]
    return RollingFit(
        result=result,
        thetas=thetas,
        var_coefs=mats,
        n_rows=int(plan.resid.shape[0]),
        intercept=np.asarray(plan.intercept),
    )


class RollingVarLiNGAM:
    """Incremental VarLiNGAM over a chunked rolling window.

    Args:
      d:             number of variables.
      chunk:         rows per pushed block (must exceed ``lags``).
      window_chunks: window length in chunks (ring capacity).
      lags:          VAR order k.
      config:        the DirectLiNGAM :class:`~repro.core.api.FitConfig`
                     for the residual fit; ``moment_chunk`` defaults to
                     the dispatcher's tuned sample block for this
                     window's shape bucket (never above the stream
                     chunk — that is the session's memory bound), so
                     the ordering moments accumulate in tuned slabs.
      reanchor_every: if > 0, rebuild the moment state from the live
                     ring every that-many slides (post window fill) to
                     cap retraction drift on non-stationary streams.
    """

    def __init__(
        self,
        d: int,
        chunk: int,
        window_chunks: int,
        *,
        lags: int = 1,
        config: api.FitConfig = api.FitConfig(compaction="staged"),
        reanchor_every: int = 0,
    ):
        if lags < 1:
            raise ValueError(f"lags must be >= 1, got {lags}")
        if chunk <= lags:
            raise ValueError(f"chunk ({chunk}) must exceed lags ({lags})")
        if config.partition is not None:
            raise ValueError(
                "RollingVarLiNGAM refits through the local/vmap plans; "
                "drop config.partition (use VarLiNGAM + fit_fn for the "
                "mesh plan)."
            )
        self.d = d
        self.chunk = chunk
        self.lags = lags
        self.reanchor_every = reanchor_every
        if config.moment_chunk is None:
            config = dataclasses.replace(
                config, moment_chunk=_tuned_moment_chunk(
                    d, chunk, window_chunks, config
                )
            )
        self.config = config
        self.ring = ChunkRing(window_chunks)
        self.aug_state = stats.init((lags + 1) * d)
        self._prev_tail: Optional[np.ndarray] = None  # newest chunk's tail
        self._lead_tail: Optional[np.ndarray] = None  # rows before oldest
        self.n_pushed = 0

    @property
    def ready(self) -> bool:
        """Whether a full window is buffered (refits allowed)."""
        return self.ring.full

    def push(self, rows) -> stats.MomentState:
        """Slide the window by one chunk: absorb ``rows``' augmented
        moments, retract the evicted chunk's.

        Returns the absorbed chunk's own augmented :class:`~repro.
        stream.stats.MomentState` — the summary this slide computed
        anyway (``update_chunk`` is ``merge(state, from_chunk(rows))``
        unrolled). The drift monitor scores served graphs from exactly
        this object, so monitoring never re-reads the chunk's rows.
        """
        # Copy unconditionally: the ring and tails hold these rows until
        # retraction, so aliasing a caller-reused buffer would silently
        # corrupt the window.
        rows = np.array(rows, dtype=np.float32, copy=True)
        if rows.shape != (self.chunk, self.d):
            raise ValueError(
                f"expected ({self.chunk}, {self.d}) rows, got {rows.shape}"
            )
        buf = rows if self._prev_tail is None else np.concatenate(
            [self._prev_tail, rows]
        )
        chunk_state = stats.from_chunk(
            jnp.asarray(lagged_rows(buf, self.lags))
        )
        self.aug_state = stats.merge(self.aug_state, chunk_state)
        evicted = self.ring.push(rows)
        if evicted is not None:
            ebuf = evicted if self._lead_tail is None else np.concatenate(
                [self._lead_tail, evicted]
            )
            self.aug_state = stats.retract_chunk(
                self.aug_state, lagged_rows(ebuf, self.lags)
            )
            self._lead_tail = evicted[-self.lags:]
            obs_metrics.inc("stream.retracts")
        self._prev_tail = rows[-self.lags:]
        self.n_pushed += 1
        if (
            self.reanchor_every
            and self.ring.full
            and self.n_pushed % self.reanchor_every == 0
        ):
            self.reanchor()
        return chunk_state

    def _window_bufs(self):
        """Live blocks with their lag context, oldest -> newest."""
        tail = self._lead_tail
        for block in self.ring:
            yield block if tail is None else np.concatenate([tail, block])
            tail = block[-self.lags:]

    def reanchor(self) -> None:
        """Rebuild the moment state from the live ring (drops all
        accumulated merge/retract rounding)."""
        state = stats.init((self.lags + 1) * self.d)
        for buf in self._window_bufs():
            state = stats.update_chunk(state, lagged_rows(buf, self.lags))
        self.aug_state = state
        obs_metrics.inc("stream.reanchors")

    def prepare_refit(self) -> RefitPlan:
        """Assemble this window's refit inputs (state-derived VAR +
        chunk-wise residual blocks); execution happens in
        :meth:`refit` or batched across sessions by the engine."""
        if not self.ready:
            raise RuntimeError(
                f"window not full: {len(self.ring)}/{self.ring.capacity} "
                "chunks buffered"
            )
        a, mats, intercept, resid_cov = _var_solve(
            self.aug_state.count,
            self.aug_state.mean,
            self.aug_state.m2,
            d=self.d,
            lags=self.lags,
        )
        blocks = [
            _residual_block(jnp.asarray(lagged_rows(buf, self.lags)), a,
                            intercept)
            for buf in self._window_bufs()
        ]
        return RefitPlan(
            resid=jnp.concatenate(blocks, axis=0),
            resid_mean=jnp.zeros((self.d,), jnp.float32),
            resid_cov=resid_cov,
            mats=np.asarray(mats),
            intercept=np.asarray(intercept),
        )

    def refit(self) -> RollingFit:
        """Re-estimate the current window's graph (single-session path;
        the serving engine batches many sessions' plans instead)."""
        plan = self.prepare_refit()
        result = api.fit_from_stats(
            plan.resid, plan.resid_mean, plan.resid_cov, self.config
        )
        return finish_refit(plan, result)


def direct_window_fit(
    chunks,
    lead_tail,
    *,
    lags: int = 1,
    config: api.FitConfig = api.FitConfig(compaction="staged"),
) -> RollingFit:
    """From-scratch oracle: the identical estimator via a direct
    two-pass over the whole window.

    Augmented rows are built in one piece, their moments computed with
    no merges or retractions, then the same VAR solve / residual /
    ``fit_from_stats`` tail runs. The rolling path must agree with this
    within fp32 merge tolerance — the parity the tests pin.
    """
    chunks = [np.ascontiguousarray(c, dtype=np.float32) for c in chunks]
    d = chunks[0].shape[1]
    buf = np.concatenate(
        ([lead_tail] if lead_tail is not None else []) + chunks
    )
    aug = lagged_rows(buf, lags)
    state = stats.from_chunk(jnp.asarray(aug))
    a, mats, intercept, resid_cov = _var_solve(
        state.count, state.mean, state.m2, d=d, lags=lags
    )
    resid = _residual_block(jnp.asarray(aug), a, intercept)
    plan = RefitPlan(
        resid=resid,
        resid_mean=jnp.zeros((d,), jnp.float32),
        resid_cov=resid_cov,
        mats=np.asarray(mats),
        intercept=np.asarray(intercept),
    )
    result = api.fit_from_stats(
        plan.resid, plan.resid_mean, plan.resid_cov, config
    )
    return finish_refit(plan, result)
