"""Data generators for the paper's experiments.

* ``simulate_lingam`` — the paper's §3.1 protocol: layered DAG (each node's
  parents come from the previous layer), effects theta ~ N(0, 1), noise
  e ~ Uniform(0, 1) (non-Gaussian, as LiNGAM requires).
* ``simulate_do`` — ground-truth interventional sampling from an
  arbitrary LiNGAM adjacency under ``do(x_j = v)``: the brute-force
  Monte-Carlo oracle the effect/intervention tests validate against.
* ``simulate_gene_perturb`` — Perturb-seq-like interventional expression
  data matched to the paper's Table-1 dimensions (no real dataset offline).
* ``simulate_var_stocks`` — stationary VAR(1) series with a LiNGAM
  instantaneous graph, matched to the paper's d=487 S&P experiment.
* ``simulate_var_breaks`` — the same VAR process with a structural
  break injected mid-series (edge flip / weight shift / noise-scale
  change), the ground truth the drift-monitor benchmarks measure
  detection delay and false-alarm rate against.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class LingamGroundTruth:
    adjacency: np.ndarray  # B[i, j] = effect of x_j on x_i
    order: np.ndarray      # a valid causal order (topological)
    data: np.ndarray       # (m, d)


def _layered_dag(d: int, n_layers: int, edge_prob: float, rng) -> np.ndarray:
    """Layered DAG per §3.1: node at layer l draws parents from layer l-1."""
    layers = np.array_split(np.arange(d), n_layers)
    b = np.zeros((d, d), dtype=np.float64)
    for l in range(1, len(layers)):
        for i in layers[l]:
            for j in layers[l - 1]:
                if rng.random() < edge_prob:
                    b[i, j] = rng.standard_normal()  # theta ~ N(0, 1)
    return b


def simulate_lingam(
    m: int = 10_000,
    d: int = 10,
    n_layers: int = 3,
    edge_prob: float = 0.5,
    noise: str = "uniform",
    seed: int = 0,
    min_effect: float = 0.3,
) -> LingamGroundTruth:
    """Generate data from x = B x + e with a layered DAG.

    ``min_effect`` rescales tiny effects away from 0 so the recovery metrics
    are not dominated by statistically invisible edges (the paper's F1≈1
    regime). Noise is Uniform(0,1) by default, per the paper.
    """
    rng = np.random.default_rng(seed)
    b = _layered_dag(d, n_layers, edge_prob, rng)
    small = (np.abs(b) < min_effect) & (b != 0.0)
    b[small] = np.sign(b[small]) * min_effect

    if noise == "uniform":
        e = rng.uniform(0.0, 1.0, size=(m, d))
    elif noise == "laplace":
        e = rng.laplace(0.0, 1.0, size=(m, d))
    else:
        raise ValueError(noise)

    # x = (I - B)^{-1} e ; B is strictly lower-block-triangular by layers.
    x = np.linalg.solve(np.eye(d) - b, e.T).T
    order = np.arange(d)  # layered construction => identity is topological
    # Shuffle variable identities so the order is non-trivial.
    perm = rng.permutation(d)
    x = x[:, perm]
    b_perm = b[np.ix_(perm, perm)]
    inv = np.empty(d, dtype=int)
    inv[perm] = np.arange(d)
    order = inv[order]  # positions of original order in permuted ids
    # order must list *permuted* ids in causal order: original node k is now
    # called inv[k]; original order was 0..d-1 by construction.
    return LingamGroundTruth(adjacency=b_perm, order=order, data=x.astype(np.float32))


def simulate_do(
    adjacency,
    do,
    m: int = 10_000,
    noise: str = "uniform",
    seed: int = 0,
) -> np.ndarray:
    """Brute-force interventional sampler: draws from the SEM under
    ``do(x_j = v_j for j, v_j in do.items())``.

    The do-operator severs each intervened variable's incoming edges
    (its row of ``B``) and pins its value before effects propagate —
    exactly the graph surgery :mod:`repro.infer.intervene` performs
    algebraically, but realized sample-by-sample so analytic effect /
    interventional-moment answers can be validated against Monte Carlo.
    Noise matches :func:`simulate_lingam` (``uniform``: U(0,1);
    ``laplace``: Laplace(0,1)); a shared ``seed`` yields common random
    numbers across calls, so finite-difference effect estimates
    ``(E[x | do(v+1)] - E[x | do(v)])`` are exact up to solver
    precision, not just in expectation.

    Returns (m, d) float32 samples.
    """
    b = np.array(adjacency, dtype=np.float64, copy=True)
    d = b.shape[0]
    rng = np.random.default_rng(seed)
    if noise == "uniform":
        e = rng.uniform(0.0, 1.0, size=(m, d))
    elif noise == "laplace":
        e = rng.laplace(0.0, 1.0, size=(m, d))
    else:
        raise ValueError(noise)
    for j, v in do.items():
        b[int(j), :] = 0.0
        e[:, int(j)] = float(v)
    x = np.linalg.solve(np.eye(d) - b, e.T).T
    return x.astype(np.float32)


def simulate_gene_perturb(
    m: int = 20_000,
    d: int = 200,
    n_interventions: int = 50,
    edge_prob: float = 0.02,
    seed: int = 0,
):
    """Perturb-seq-like data: sparse LiNGAM SEM + single-gene interventions.

    Returns (data, intervention_targets, adjacency). Each sample has a
    target gene whose value is set by the intervention (do-operator) before
    effects propagate; target = -1 means observational (control).
    """
    rng = np.random.default_rng(seed)
    b = np.zeros((d, d))
    for i in range(1, d):
        parents = rng.random(i) < edge_prob
        b[i, :i][parents] = rng.standard_normal(parents.sum()) * 0.8
    targets = np.full(m, -1, dtype=np.int64)
    n_int = int(0.8 * m)
    genes = rng.integers(0, n_interventions, size=n_int)
    targets[:n_int] = genes

    e = rng.laplace(0.0, 1.0, size=(m, d))
    x = np.zeros((m, d), dtype=np.float64)
    # Topological order is 0..d-1 by construction; propagate row by row.
    for i in range(d):
        contrib = x @ b[i]  # parents already filled (j < i)
        x[:, i] = contrib + e[:, i]
        hit = targets == i
        x[hit, i] = 5.0  # do(x_i = const) — strong over-expression
    return x.astype(np.float32), targets, b


def simulate_var_stocks(
    m: int = 4000,
    d: int = 487,
    edge_prob: float = 0.01,
    ar_scale: float = 0.2,
    seed: int = 0,
):
    """Stationary VAR(1) with a LiNGAM instantaneous graph (stock-like).

    Returns (series, b0, m1): x(t) = B0 x(t) + M1 x(t-1) + e(t), i.e.
    x(t) = (I-B0)^{-1} (M1 x(t-1) + e(t)).
    """
    rng = np.random.default_rng(seed)
    b0 = np.zeros((d, d))
    for i in range(1, d):
        parents = rng.random(i) < edge_prob
        b0[i, :i][parents] = rng.standard_normal(parents.sum()) * 0.5
    m1 = rng.standard_normal((d, d)) * (rng.random((d, d)) < edge_prob)
    m1 *= ar_scale
    # Spectral-radius guard for stationarity.
    a = np.linalg.solve(np.eye(d) - b0, m1)
    rad = np.max(np.abs(np.linalg.eigvals(a)))
    if rad >= 0.95:
        m1 *= 0.9 / rad
    inv = np.linalg.inv(np.eye(d) - b0)
    x = np.zeros((m, d))
    e = rng.laplace(0.0, 1.0, size=(m, d))
    for t in range(1, m):
        x[t] = inv @ (m1 @ x[t - 1] + e[t])
    return x.astype(np.float32), b0, m1


BREAK_KINDS = ("edge_flip", "weight_shift", "noise_scale")


@dataclasses.dataclass
class VarBreak:
    """Ground truth of one simulated structural break."""

    series: np.ndarray      # (m, d) float32, break at row ``at``
    kind: str               # which mechanism changed
    at: int                 # first row generated by the new mechanism
    variable: int           # the variable whose mechanism changed
    b0_pre: np.ndarray      # (d, d) instantaneous graph before
    b0_post: np.ndarray     # (d, d) after (== pre for noise_scale)
    m1: np.ndarray          # (d, d) lag-1 matrix (unchanged)


def simulate_var_breaks(
    m: int = 4000,
    d: int = 12,
    kind: str = "noise_scale",
    at: Optional[int] = None,
    magnitude: float = 3.0,
    edge_prob: float = 0.15,
    ar_scale: float = 0.2,
    seed: int = 0,
) -> VarBreak:
    """VAR(1)+LiNGAM series with one structural break at row ``at``
    (default: mid-series). Three break kinds, matching the drift
    monitor's alert taxonomy:

    * ``"noise_scale"``  — one variable's exogenous-noise scale is
      multiplied by ``magnitude`` (graph unchanged);
    * ``"weight_shift"`` — one existing instantaneous edge's weight is
      shifted by ``magnitude`` times its magnitude (sign kept; the
      intercept-free analogue of a level shift, surfacing through the
      residual's second moments);
    * ``"edge_flip"``    — one instantaneous edge is removed and a new
      one (same child, different parent) appears, breaking the served
      graph's residual independence.

    The affected ``variable`` is always the *child* of the changed
    mechanism — the variable whose structural equation no longer holds
    — which is what the monitor should implicate. Pre-break dynamics
    come from the :func:`simulate_var_stocks` construction (laplace
    noise, stationarity-guarded lag matrix) so stationary-stream
    false-alarm calibration and break detection share one process
    family.
    """
    if kind not in BREAK_KINDS:
        raise ValueError(f"kind must be one of {BREAK_KINDS}, got {kind!r}")
    rng = np.random.default_rng(seed)
    at = m // 2 if at is None else int(at)

    b0 = np.zeros((d, d))
    for i in range(1, d):
        parents = rng.random(i) < edge_prob
        b0[i, :i][parents] = rng.standard_normal(parents.sum()) * 0.5
    # Guarantee at least one edge to break (tiny d / unlucky seed).
    if not np.any(b0):
        b0[d - 1, 0] = 0.5
    m1 = rng.standard_normal((d, d)) * (rng.random((d, d)) < edge_prob)
    m1 *= ar_scale
    a = np.linalg.solve(np.eye(d) - b0, m1)
    rad = np.max(np.abs(np.linalg.eigvals(a)))
    if rad >= 0.95:
        m1 *= 0.9 / rad

    # Break the strongest edge: the change must be statistically
    # meaningful for detection-delay measurements to mean anything.
    ei, ej = np.unravel_index(np.argmax(np.abs(b0)), b0.shape)
    noise_scale = np.ones(d)
    b0_post = b0.copy()
    if kind == "noise_scale":
        variable = int(ei)
        scale_post = noise_scale.copy()
        scale_post[variable] = magnitude
    elif kind == "weight_shift":
        variable = int(ei)
        b0_post[ei, ej] += np.sign(b0[ei, ej]) * magnitude * abs(b0[ei, ej])
        scale_post = noise_scale
    else:  # edge_flip
        variable = int(ei)
        b0_post[ei, ej] = 0.0
        # New parent for the same child: any earlier variable without
        # an existing edge into it (fall back to re-weighting ej).
        free = [j for j in range(ei) if j != ej and b0[ei, j] == 0.0]
        nj = free[rng.integers(len(free))] if free else int(ej)
        b0_post[ei, nj] = np.sign(rng.standard_normal() + 1e-9) * (
            magnitude * 0.3
        )
        scale_post = noise_scale

    inv_pre = np.linalg.inv(np.eye(d) - b0)
    inv_post = np.linalg.inv(np.eye(d) - b0_post)
    x = np.zeros((m, d))
    e = rng.laplace(0.0, 1.0, size=(m, d))
    for t in range(1, m):
        if t < at:
            x[t] = inv_pre @ (m1 @ x[t - 1] + e[t] * noise_scale)
        else:
            x[t] = inv_post @ (m1 @ x[t - 1] + e[t] * scale_post)
    return VarBreak(
        series=x.astype(np.float32), kind=kind, at=at, variable=variable,
        b0_pre=b0, b0_post=b0_post, m1=m1,
    )
