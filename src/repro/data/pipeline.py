"""Deterministic, host-shardable synthetic token pipeline with prefetch.

The stream is a pure function of (seed, step, host_shard), so a restarted
(or re-scaled) job resumes sample-exact from the step recorded in the
checkpoint manifest — the elastic-restart contract of the trainer.
A background thread keeps a small prefetch queue full (straggler
mitigation lever on real hosts: data never blocks the step).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


class TokenStream:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        seed: int = 0,
        start_step: int = 0,
        host_index: int = 0,
        host_count: int = 1,
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.step = start_step
        self.host_index = host_index
        self.host_count = host_count
        assert shape.global_batch % host_count == 0
        self.local_batch = shape.global_batch // host_count
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _gen(self, step: int) -> Dict[str, np.ndarray]:
        # Independent RNG per (seed, step, host) — order-independent.
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )
        b, s = self.local_batch, self.shape.seq_len
        # Zipf-ish marginal over the vocab, like natural text.
        z = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        tokens = (z % (self.cfg.vocab_size - 1)) + 1
        batch = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if self.cfg.family in ("audio", "vlm"):
            batch["frontend"] = rng.normal(
                size=(b, self.cfg.n_frontend_tokens, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch

    def _fill(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._gen(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def state(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    def close(self):
        self._stop.set()
