"""Root-cause attribution by structural-noise decomposition.

In a LiNGAM SEM an observed sample decomposes *exactly* into its
exogenous noise terms: ``x - mu = A e~`` with ``A = (I - B)^{-1}`` and
``e~ = (I - B)(x - mu)`` — one masked matmul per sample, no solve
needed for the decomposition itself. Attribution of an anomalous
sample is then linear algebra, not search:

  * **which variable's mechanism broke** — the standardized noise
    scores ``z_j = e~_j / sqrt(Var e_j)``: under the fitted model each
    is ~unit-scale, so the variable whose *own* noise term is extreme
    is the root cause (its descendants look anomalous too, but their
    deviations are explained by propagation).
  * **who moved a given target** — the exact additive split
    ``x_i - mu_i = sum_j A[i, j] e~_j``: contribution of root ``j`` to
    target ``i`` is ``A[i, j] e~_j``, summing to the target's deviation
    by construction (pinned by the tests).

Everything is batched over samples (plain matmuls) and jit/vmap-clean;
:func:`attribute` is the host-facing entry, and for wide row batches it
bounds device memory by slabbing the sample axis with the kernel
dispatcher's tuned sample block (:func:`repro.kernels.tune.dispatch`)
— the same decision point the moment kernels use.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api

_EPS = 1e-12


def noise_terms_impl(adjacency, rows, mean):
    """(n, d) centered structural noise ``e~ = (I - B)(x - mu)``."""
    xc = rows.astype(jnp.float32) - mean.astype(jnp.float32)[None, :]
    return xc - xc @ adjacency.astype(jnp.float32).T


def noise_scores_impl(adjacency, rows, mean, resid_var):
    """(n, d) standardized noise scores ``e~_j / sqrt(Var e_j)``."""
    e = noise_terms_impl(adjacency, rows, mean)
    return e * jax.lax.rsqrt(jnp.maximum(resid_var, _EPS))[None, :]


def contributions_impl(adjacency, order, rows, mean, target):
    """(n, d) additive contributions of each root's noise term to the
    ``target`` variable's deviation: ``A[target, j] * e~_j`` (rows sum
    to ``x_target - mu_target``). ``target`` may be a traced index.
    Only the needed row of ``A`` is solved for (O(d^2)), so repeating
    this per sample slab costs nothing next to the slab's own matmul."""
    from .effects import target_effects_row

    t_row = target_effects_row(adjacency, order, target)
    e = noise_terms_impl(adjacency, rows, mean)
    return e * t_row[None, :]


@jax.jit
def _rca_jit(adjacency, order, rows, mean, resid_var, target):
    scores = noise_scores_impl(adjacency, rows, mean, resid_var)
    contrib = contributions_impl(adjacency, order, rows, mean, target)
    return scores, contrib


@dataclasses.dataclass
class RCAResult:
    """Attribution of a batch of (anomalous) samples."""

    scores: np.ndarray         # (n, d) standardized noise z-scores
    root: np.ndarray           # (n,) argmax |z| — the implicated variable
    target: Optional[int]      # attribution target (None = none requested)
    contributions: Optional[np.ndarray]  # (n, d) A[target, :] * e~, or None

    def ranking(self, row: int = 0, top_k: int = 5):
        """[(variable, z-score)] for one sample, by |z| descending."""
        z = self.scores[row]
        idx = np.argsort(-np.abs(z))[:top_k]
        return [(int(j), float(z[j])) for j in idx]


def _sample_slab(n: int, d: int, backend, tune: str, chunk) -> int:
    """Tuned sample-slab size for the noise pass: the dispatcher's
    ``bm`` block for this (n, d) bucket, i.e. the same measured
    decision the chunked moment kernels use; falls back to the full
    batch when the table offers nothing smaller. Shared with the query
    engine's RCA buckets."""
    from repro.kernels import tune as ktune

    plan = ktune.dispatch(
        "pairwise_moment_sums_chunked", (n, d),
        backend=backend, mode=tune, chunk=chunk,
    )
    return int(plan.bm) if plan.bm else n


def _pad_rows(block: np.ndarray, slab: int, axis: int = 0) -> np.ndarray:
    """Zero-pad a slab along the sample axis to a bounded shape set.

    Full slabs pass through; short blocks (ragged tails, small
    batches) round up to the next power of two capped at ``slab`` —
    so steady-state traffic with varying row counts compiles at most
    log2(slab) + 1 program shapes instead of one per distinct length.
    Padding rows are all-zero and the per-row computations are
    independent, so real rows are bit-unchanged (callers trim).
    """
    from repro.core.batched import pow2_bucket

    k = block.shape[axis]
    target = pow2_bucket(k, slab)
    if target == k:
        return block
    pad = [(0, 0)] * block.ndim
    pad[axis] = (0, target - k)
    return np.pad(block, pad)


def attribute(
    result: api.FitResult,
    rows,
    *,
    mean=None,
    target: Optional[int] = None,
    chunk: Optional[int] = None,
    backend: Optional[str] = None,
    tune: str = "cache",
) -> RCAResult:
    """Root-cause attribution of ``rows`` under a fitted graph.

    Args:
      result: the fitted graph (adjacency + order + resid_var).
      rows:   (n, d) samples to attribute (or (d,) for one).
      mean:   (d,) observational mean of the training data (None =
              centered data).
      target: optional variable index; when given, the exact additive
              contribution split toward that variable is returned too.
      chunk:  bound on the sample slab per device pass; None asks the
              kernel dispatcher for this shape's tuned block.
      tune:   dispatcher mode for the slab decision ("off"/"cache"/
              "auto" — see :mod:`repro.kernels.tune`).
    """
    rows = np.asarray(rows, np.float32)
    if rows.ndim == 1:
        rows = rows[None, :]
    n, d = rows.shape
    mu = (
        jnp.zeros((d,), jnp.float32) if mean is None
        else jnp.asarray(mean, jnp.float32)
    )
    slab = chunk or _sample_slab(n, d, backend, tune, chunk)
    tgt = jnp.int32(0 if target is None else int(target))
    scores_parts, contrib_parts = [], []
    for start in range(0, n, slab):
        block = rows[start:start + slab]
        k = block.shape[0]
        s, c = _rca_jit(
            result.adjacency, result.order,
            jnp.asarray(_pad_rows(block, slab)), mu,
            jnp.asarray(result.resid_var), tgt,
        )
        scores_parts.append(np.asarray(s)[:k])
        contrib_parts.append(np.asarray(c)[:k])
    scores = np.concatenate(scores_parts, axis=0)
    contributions = (
        np.concatenate(contrib_parts, axis=0) if target is not None else None
    )
    return RCAResult(
        scores=scores,
        root=np.argmax(np.abs(scores), axis=1),
        target=target,
        contributions=contributions,
    )


def drift_root_candidates(
    adjacency,
    order,
    drift_scores,
    *,
    top_k: int = 3,
):
    """Rank candidate root variables behind a drift episode.

    ``drift_scores`` are the graph-health monitor's per-variable
    sequential-test levels (:meth:`GraphHealthMonitor.variable_scores`)
    — already expressed in the structural-noise frame, i.e. per-noise,
    with propagation through the served graph deconvolved, exactly like
    the per-sample z-scores above. But a broken *upstream* mechanism
    still leaks into descendants' residuals (their regressions were fit
    to the old mechanism), so ties are broken causally: each variable's
    own score is discounted by the strongest ancestral score, ancestors
    judged by the fitted total-effect matrix ``A = (I - B)^{-1}``.
    A variable drifting alone keeps its full score; one whose drifting
    ancestor explains it ranks below that ancestor.

    Returns ``[(variable, drift score)]``, strongest candidate first —
    the same shape as :meth:`RCAResult.ranking`.
    """
    from .effects import total_effects_impl

    z = np.abs(np.asarray(drift_scores, np.float32))
    d = z.shape[0]
    a = np.asarray(total_effects_impl(
        jnp.asarray(adjacency), jnp.asarray(order)
    ))
    reach = (np.abs(a) > _EPS) & ~np.eye(d, dtype=bool)  # [i, j]: j ancestor of i
    anc_peak = np.where(reach, z[None, :], 0.0).max(axis=1)
    adjusted = z - 0.5 * np.minimum(anc_peak, z)
    idx = np.argsort(-adjusted)[:top_k]
    return [(int(j), float(z[j])) for j in idx if z[j] > 0.0]
