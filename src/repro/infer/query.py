"""Query engine: micro-batched causal queries over fitted graphs.

A fitted graph should be a queryable object, not a matrix dump. This
module gives serving traffic that object:

  * :class:`FittedGraph` — a :class:`~repro.core.api.FitResult` plus
    the observational context queries need (data mean, structural-noise
    moments), buildable from a one-shot fit (:meth:`FittedGraph.
    from_result`) or a live streaming session (:meth:`FittedGraph.
    from_session` — moments come from the session's incremental store,
    no rows re-read).
  * :class:`EffectQuery` / :class:`InterventionQuery` /
    :class:`RCAQuery` — the three request kinds.
  * :class:`QueryEngine` — admits a mixed list of requests, buckets
    them by (query kind, graph shape), pads each bucket to the
    power-of-two micro-batch, and executes it as **one** compiled
    device-parallel program (``jit(vmap(...))`` over the bucket).
    Compilation happens once per (kind, shape) signature — recorded in
    the public compile log (``repro.obs.compile_log``, ops
    ``query.effects`` / ``query.intervention`` / ``query.rca``) and
    pinned by ``tests/test_infer.py`` — so steady-state traffic never
    traces. Per-(kind, shape) bucket latencies land in
    ``repro.obs.metrics`` (series ``query.bucket_s``) when telemetry
    is enabled.

Interventions use dense (d,) do-masks (:func:`repro.infer.intervene.
do_arrays`), so requests targeting *different* variables still share a
bucket. The serving side
(:meth:`repro.serve.engine.CausalDiscoveryEngine.query`) resolves
stream-session ids to :class:`FittedGraph`\\ s and delegates here.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import api, batched
from repro.obs import compile_log
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile

from . import effects as effects_lib
from . import intervene as intervene_lib
from . import rca as rca_lib

# Each batch kernel records its trace body in the public compile log
# (one event per (kind, shape-bucket) signature, never in steady state)
# — the single-compile contract tests/test_infer.py pins through
# repro.obs.compile_log.


@jax.jit
def _effects_batch(adj, order):
    compile_log.record("query.effects", shape=adj.shape)
    return jax.vmap(effects_lib.total_effects_impl)(adj, order)


@jax.jit
def _intervene_batch(adj, order, mask, values, noise_mean, noise_var):
    compile_log.record("query.intervention", shape=adj.shape)
    mu = jax.vmap(intervene_lib.interventional_mean_impl)(
        adj, order, mask, values, noise_mean
    )
    cov = jax.vmap(intervene_lib.interventional_cov_impl)(
        adj, order, mask, noise_var
    )
    return mu, cov


@jax.jit
def _rca_batch(adj, order, rows, mean, resid_var, target):
    compile_log.record("query.rca", shape=rows.shape)
    scores = jax.vmap(rca_lib.noise_scores_impl)(adj, rows, mean, resid_var)
    contrib = jax.vmap(rca_lib.contributions_impl)(
        adj, order, rows, mean, target
    )
    return scores, contrib


@dataclasses.dataclass
class FittedGraph:
    """A fitted graph plus the observational context queries consume."""

    result: api.FitResult
    mean: np.ndarray        # (d,) observational mean of the fitted space
    noise_mean: np.ndarray  # (d,) E[e] implied by the moments
    noise_var: np.ndarray   # (d,) Var e (resid_var unless moments given)
    sid: Optional[str] = None  # originating stream session, if any: the
    #                            serving engine re-snapshots live sessions
    #                            on every query, so re-issued requests
    #                            never answer from a stale estimate

    @property
    def d(self) -> int:
        return int(self.result.order.shape[0])

    @classmethod
    def from_result(cls, result: api.FitResult, *, mean=None, cov=None
                    ) -> "FittedGraph":
        """Wrap a one-shot fit. ``mean``/``cov`` are the training data's
        observational moments; omitted, the data is taken as centered
        and the noise variances fall back to ``resid_var``."""
        d = int(result.order.shape[0])
        mu = (np.zeros((d,), np.float32) if mean is None
              else np.asarray(mean, np.float32))
        if cov is None:
            r = np.eye(d, dtype=np.float32) - np.asarray(result.adjacency)
            nm = r @ mu
            nv = np.asarray(result.resid_var, np.float32)
        else:
            nm_j, nv_j = intervene_lib.noise_stats(
                jnp.asarray(result.adjacency), jnp.asarray(mu),
                jnp.asarray(cov),
            )
            nm, nv = np.asarray(nm_j), np.asarray(nv_j)
        return cls(result=result, mean=mu, noise_mean=nm, noise_var=nv)

    @classmethod
    def from_session(cls, session) -> "FittedGraph":
        """Wrap a streaming session's current estimate.

        The instantaneous graph ``B0`` comes from the session's last
        refit; the observational mean is the rolling window's (sliced
        from the lag-augmented moment store — no rows re-read), and the
        noise statistics are ``(I - B0) mu`` with the refit's residual
        variances. Queries thus describe the *contemporaneous* SEM at
        the window's operating point: RCA rows should be deviations of
        raw samples (the lag-driven part shows up in the noise terms),
        and interventional moments are contemporaneous-equilibrium
        answers, not multi-step forecasts (use
        :func:`repro.infer.effects.var_irf` for lag propagation).
        """
        if session.last_fit is None:
            raise ValueError(
                f"session {session.sid!r} has no estimate yet "
                "(window not full or no refit flushed)"
            )
        result = session.last_fit.result
        d = int(result.order.shape[0])
        state = session.rolling.aug_state
        mu = np.asarray(state.mean, np.float32)[:d]
        r = np.eye(d, dtype=np.float32) - np.asarray(result.adjacency)
        return cls(
            result=result,
            mean=mu,
            noise_mean=r @ mu,
            noise_var=np.asarray(result.resid_var, np.float32),
            sid=session.sid,
        )


GraphRef = Union["FittedGraph", api.FitResult, str]


@dataclasses.dataclass
class EffectQuery:
    """Total-effect matrix of one graph. Answer: ``effects`` (d, d)."""

    graph: GraphRef
    effects: Optional[np.ndarray] = None


@dataclasses.dataclass
class InterventionQuery:
    """Post-intervention moments under ``do``. Answer: ``mean`` (d,),
    ``cov`` (d, d)."""

    graph: GraphRef
    do: Mapping[int, float] = dataclasses.field(default_factory=dict)
    mean: Optional[np.ndarray] = None
    cov: Optional[np.ndarray] = None


@dataclasses.dataclass
class RCAQuery:
    """Root-cause attribution of ``rows``. Answer: ``result``
    (:class:`repro.infer.rca.RCAResult`)."""

    graph: GraphRef
    rows: np.ndarray = None
    target: Optional[int] = None
    result: Optional[rca_lib.RCAResult] = None


class QueryEngine:
    """Shape-bucketed, micro-batched execution of causal queries.

    Mixed request lists are grouped by (kind, d) — RCA additionally by
    its row-batch length — padded to the next power-of-two bucket
    (<= ``batch_size``, by repeating the first request's graph, so a
    singleton costs one query, not ``batch_size``), and each bucket
    runs as a single ``jit(vmap(...))`` program. The compile cache is
    keyed by the bucket signature, so a steady query mix compiles once
    per (kind, shape) and never again.
    """

    def __init__(self, *, batch_size: int = 8,
                 backend: Optional[str] = None, tune: str = "cache"):
        self.batch_size = batch_size
        self.backend = backend
        self.tune = tune

    def _bucket(self, n: int) -> int:
        return batched.pow2_bucket(n, self.batch_size)

    @staticmethod
    def _resolve(q) -> FittedGraph:
        if isinstance(q.graph, api.FitResult):
            q.graph = FittedGraph.from_result(q.graph)
        if not isinstance(q.graph, FittedGraph):
            raise TypeError(
                f"unresolved graph ref {type(q.graph).__name__}: string "
                "session ids are resolved by CausalDiscoveryEngine.query"
            )
        return q.graph

    def run(self, queries: List[object]) -> List[object]:
        buckets: Dict[object, List[object]] = {}
        for q in queries:
            g = self._resolve(q)
            if isinstance(q, EffectQuery):
                key = ("effects", g.d)
            elif isinstance(q, InterventionQuery):
                key = ("intervention", g.d)
            elif isinstance(q, RCAQuery):
                rows = np.asarray(q.rows, np.float32)
                q.rows = rows[None, :] if rows.ndim == 1 else rows
                key = ("rca", g.d, q.rows.shape[0])
            else:
                raise TypeError(f"unknown query type {type(q).__name__}")
            buckets.setdefault(key, []).append(q)
        for key, group in buckets.items():
            runner = getattr(self, f"_run_{key[0]}")
            with obs.span(
                "query.bucket", kind=key[0], d=key[1], n=len(group)
            ):
                t0 = time.perf_counter()
                for start in range(0, len(group), self.batch_size):
                    part = group[start:start + self.batch_size]
                    runner(
                        part
                        + [part[0]] * (self._bucket(len(part)) - len(part))
                    )
                obs_metrics.observe(
                    "query.bucket_s", time.perf_counter() - t0,
                    kind=key[0], d=key[1],
                )
                obs_metrics.inc("query.requests", len(group), kind=key[0])
        return queries

    @staticmethod
    def _stack_graphs(part):
        gs = [q.graph for q in part]
        adj = jnp.stack([jnp.asarray(g.result.adjacency) for g in gs])
        order = jnp.stack([jnp.asarray(g.result.order) for g in gs])
        return gs, adj, order

    def _run_effects(self, part):
        _, adj, order = self._stack_graphs(part)
        out = np.asarray(obs_profile.call(
            _effects_batch, adj, order,
            op="query.effects", shape=adj.shape,
        ))
        for i, q in enumerate(part):
            q.effects = out[i]

    def _run_intervention(self, part):
        gs, adj, order = self._stack_graphs(part)
        d = gs[0].d
        masks, values = zip(*(intervene_lib.do_arrays(d, q.do) for q in part))
        mu, cov = obs_profile.call(
            _intervene_batch, adj, order,
            jnp.asarray(np.stack(masks)), jnp.asarray(np.stack(values)),
            jnp.asarray(np.stack([g.noise_mean for g in gs])),
            jnp.asarray(np.stack([g.noise_var for g in gs])),
            op="query.intervention", shape=adj.shape,
        )
        mu, cov = np.asarray(mu), np.asarray(cov)
        for i, q in enumerate(part):
            q.mean, q.cov = mu[i], cov[i]

    def _run_rca(self, part):
        gs, adj, order = self._stack_graphs(part)
        rows = np.stack([q.rows for q in part])  # (b, n, d)
        _, n, d = rows.shape
        # Heavy reduction: the per-program row slab is the kernel
        # dispatcher's tuned sample block for this (n, d) bucket, under
        # the engine's backend/tune mode — padded (zero rows, trimmed
        # below) so ragged tails reuse a bounded set of compiles.
        slab = rca_lib._sample_slab(n, d, self.backend, self.tune, None)
        targets = jnp.asarray(
            [0 if q.target is None else int(q.target) for q in part],
            jnp.int32,
        )
        means = jnp.asarray(np.stack([g.mean for g in gs]))
        noise_var = jnp.asarray(np.stack([g.noise_var for g in gs]))
        scores_parts, contrib_parts = [], []
        for start in range(0, n, slab):
            block = rows[:, start:start + slab]
            k = block.shape[1]
            padded = jnp.asarray(rca_lib._pad_rows(block, slab, axis=1))
            s, c = obs_profile.call(
                _rca_batch, adj, order, padded, means, noise_var, targets,
                op="query.rca", shape=padded.shape,
            )
            scores_parts.append(np.asarray(s)[:, :k])
            contrib_parts.append(np.asarray(c)[:, :k])
        scores = np.concatenate(scores_parts, axis=1)
        contrib = np.concatenate(contrib_parts, axis=1)
        for i, q in enumerate(part):
            q.result = rca_lib.RCAResult(
                scores=scores[i],
                root=np.argmax(np.abs(scores[i]), axis=1),
                target=q.target,
                contributions=contrib[i] if q.target is not None else None,
            )
