"""Do-operator surgery: interventional moments from observational ones.

``do(x_S = v)`` on a linear SEM ``x = B x + e`` severs the *incoming*
edges of every intervened variable (its rows of ``B``) and pins its
value; the post-intervention distribution then follows from the
mutilated graph and the noise statistics alone:

    mu' solves (I - B') mu' = c,   c_i = v_i (i in S) else E[e_i]
    Sigma' = A' D' A'^T,           A' = (I - B')^{-1},
                                   D' = diag(Var e), zero on S

Both are triangular solves in the fit's causal order (mutilation only
*removes* edges, so the order still triangularizes ``B'``) — no dense
inverse, and every function here is jit/vmap-clean: the query engine
maps them over micro-batches of interventions with dense (d,) do-masks
so mixed target sets share one compiled program.

The noise statistics come from *observational* moments via
:func:`noise_stats` — ``E[e] = (I - B) mu`` and
``Var e = diag((I - B) Sigma (I - B)^T)``. A streaming session's
incremental moment store already holds ``mu``/``Sigma``
(:class:`repro.stream.stats.MomentState`), so
:func:`interventional_from_state` answers interventional queries
without re-reading a single row.
"""

from __future__ import annotations

from typing import Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api

_VAR_EPS = 0.0  # noise variances may be exactly zero (pinned nodes)


def mutilate(adjacency, do_mask):
    """Graph surgery: sever the incoming edges (rows) of every
    intervened variable. ``do_mask`` is a (d,) bool mask."""
    return jnp.where(do_mask[:, None], 0.0, adjacency)


def do_arrays(d: int, do: Mapping[int, float]) -> Tuple[np.ndarray, np.ndarray]:
    """Dense (mask, values) encoding of a ``{var: value}`` intervention.

    Dense (d,) arrays keep every intervention the same shape, so a
    micro-batch of queries with *different* target sets still executes
    as one vmapped program (the batching contract the query engine
    relies on).
    """
    mask = np.zeros((d,), bool)
    values = np.zeros((d,), np.float32)
    for j, v in do.items():
        mask[int(j)] = True
        values[int(j)] = float(v)
    return mask, values


def noise_stats(adjacency, mean, cov):
    """Structural-noise moments implied by observational moments.

    For ``x = B x + e``: ``E[e] = (I - B) mu`` and (with independent
    noise, as LiNGAM assumes) ``Var e_i = ((I - B) Sigma (I - B)^T)_ii``.
    Returns ``(noise_mean (d,), noise_var (d,))``.
    """
    b = adjacency.astype(jnp.float32)
    r = jnp.eye(b.shape[0], dtype=b.dtype) - b
    noise_mean = r @ mean.astype(jnp.float32)
    noise_var = jnp.maximum(
        jnp.einsum("ij,jk,ik->i", r, cov.astype(jnp.float32), r), _VAR_EPS
    )
    return noise_mean, noise_var


def interventional_mean_impl(adjacency, order, do_mask, do_values, noise_mean):
    """(d,) post-intervention mean by triangular solve in causal order."""
    from .effects import _positions

    b = mutilate(adjacency.astype(jnp.float32), do_mask)
    c = jnp.where(do_mask, do_values, noise_mean).astype(jnp.float32)
    d = b.shape[0]
    bo = b[order][:, order]
    eye = jnp.eye(d, dtype=b.dtype)
    mu_ord = jax.scipy.linalg.solve_triangular(
        eye - bo, c[order][:, None], lower=True, unit_diagonal=True
    )[:, 0]
    return mu_ord[_positions(order)]


def interventional_cov_impl(adjacency, order, do_mask, noise_var):
    """(d, d) post-intervention covariance ``A' D' A'^T`` (intervened
    variables are pinned: zero variance rows/columns)."""
    from .effects import total_effects_impl

    b = mutilate(adjacency.astype(jnp.float32), do_mask)
    a = total_effects_impl(b, order)
    var = jnp.where(do_mask, 0.0, noise_var.astype(jnp.float32))
    return (a * var[None, :]) @ a.T


@jax.jit
def _interventional_jit(adjacency, order, do_mask, do_values,
                        noise_mean, noise_var):
    return (
        interventional_mean_impl(adjacency, order, do_mask, do_values,
                                 noise_mean),
        interventional_cov_impl(adjacency, order, do_mask, noise_var),
    )


def interventional_moments(
    result: api.FitResult,
    do: Mapping[int, float],
    *,
    mean=None,
    cov=None,
):
    """Post-intervention (mean, covariance) of a fitted graph.

    ``mean``/``cov`` are the *observational* moments of the data the
    graph was fitted on (a sample mean/covariance, or a streaming
    moment store's — see :func:`interventional_from_state`). With
    ``mean=None`` the data is taken as centered; with ``cov=None`` the
    noise variances fall back to the fit's ``resid_var`` diagnostics
    (exact for the OLS pruner, which makes residuals empirically
    uncorrelated with predecessors).
    """
    d = int(result.order.shape[0])
    do_mask, do_values = do_arrays(d, do)
    mean = (
        jnp.zeros((d,), jnp.float32) if mean is None
        else jnp.asarray(mean, jnp.float32)
    )
    if cov is None:
        r = jnp.eye(d, dtype=jnp.float32) - result.adjacency
        noise_mean = r @ mean
        noise_var = jnp.asarray(result.resid_var, jnp.float32)
    else:
        noise_mean, noise_var = noise_stats(
            result.adjacency, mean, jnp.asarray(cov)
        )
    mu, sigma = _interventional_jit(
        result.adjacency,
        result.order,
        jnp.asarray(do_mask),
        jnp.asarray(do_values),
        noise_mean,
        noise_var,
    )
    return np.asarray(mu), np.asarray(sigma)


def interventional_from_state(
    result: api.FitResult,
    state,
    do: Mapping[int, float],
):
    """Interventional moments straight from a streaming moment store.

    ``state`` is a :class:`repro.stream.stats.MomentState` over the
    fitted variables — or a *lag-augmented* one (a rolling VarLiNGAM
    session's ``aug_state``), whose leading (d, d) block holds the
    instantaneous moments; the block is sliced out here. No rows are
    re-read: the mean/covariance the do-calculus needs are exactly the
    sufficient statistics the stream already maintains.
    """
    d = int(result.order.shape[0])
    mean = jnp.asarray(state.mean)[:d]
    cov = jnp.asarray(state.covariance)[:d, :d]
    return interventional_moments(result, do, mean=mean, cov=cov)
