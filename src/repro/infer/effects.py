"""Total causal effects from fitted graphs — triangular solves, no inverses.

For a LiNGAM SEM ``x = B x + e`` the total-effect matrix is
``T = (I - B)^{-1}``: ``T[i, j]`` is the change in ``x_i`` per unit
exogenous shift of ``x_j``, summed over every directed path. The fit
guarantees ``B`` is strictly lower triangular *in causal order*, so the
inverse is never formed densely: :func:`total_effects_impl` permutes
``B`` into causal order, runs one unit-lower-triangular solve against
``I``, and permutes back — O(d^3/3) FLOPs, no pivoting, and every step
is a gather or a solve with batching rules, so the whole thing is
jit/vmap-clean (the batched engine maps it over bootstrap resamples,
the query engine over request micro-batches).

Also here:

  * :func:`effects_avoiding` / :func:`effects_through` — path-specific
    effects by graph surgery: severing the *outgoing* edges of a node
    set blocks exactly the paths through it, so
    ``through = total - avoiding``.
  * :func:`var_irf` — lag-propagated effects of a VarLiNGAM fit: the
    structural impulse responses ``Psi_h = Phi_h (I - B0)^{-1}`` of the
    VAR recursion ``Phi_h = sum_tau M_tau Phi_{h-tau}``, as one scan.
  * :func:`bootstrap_effects` — effect confidence intervals: the
    batched engine refits every resample *and* its total-effect matrix
    inside one compiled program
    (:func:`repro.core.batched.bootstrap_fits_with`), so the CI costs
    one dispatch more than the edge-probability bootstrap.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, batched


def _positions(order):
    """pos[v] = position of variable v in the causal order."""
    d = order.shape[0]
    return (
        jnp.zeros((d,), order.dtype)
        .at[order]
        .set(jnp.arange(d, dtype=order.dtype))
    )


def total_effects_impl(adjacency, order):
    """(d, d) total effects ``(I - B)^{-1}`` via triangular solve.

    ``adjacency`` is the fit's ``B`` (``B[i, j]`` = direct effect of
    ``x_j`` on ``x_i``), ``order`` its causal order (position p holds
    the variable index). The diagonal is 1 (every variable moves
    one-for-one with its own noise term). Unjitted trace body — compose
    under ``jit``/``vmap`` freely; :func:`total_effects` is the jitted
    single-result entry.
    """
    b = adjacency.astype(jnp.float32)
    d = b.shape[0]
    bo = b[order][:, order]  # strictly lower triangular by construction
    eye = jnp.eye(d, dtype=b.dtype)
    t_ord = jax.scipy.linalg.solve_triangular(
        eye - bo, eye, lower=True, unit_diagonal=True
    )
    pos = _positions(order)
    return t_ord[pos][:, pos]


@jax.jit
def total_effects(result: api.FitResult):
    """Total-effect matrix of one fit: ``T[i, j]`` = total effect of
    ``x_j`` on ``x_i`` (1 on the diagonal)."""
    return total_effects_impl(result.adjacency, result.order)


def target_effects_row(adjacency, order, target):
    """One row of the total-effect matrix: ``T[target, :]``.

    A single transposed unit-triangular solve — O(d^2), not the full
    O(d^3) matrix solve — so per-sample-slab consumers (RCA
    contribution splits) can recompute it in-trace for free.
    ``target`` may be a traced index.
    """
    b = adjacency.astype(jnp.float32)
    d = b.shape[0]
    bo = b[order][:, order]
    pos = _positions(order)
    rhs = jax.nn.one_hot(pos[target], d, dtype=b.dtype)
    z = jax.scipy.linalg.solve_triangular(
        (jnp.eye(d, dtype=b.dtype) - bo).T, rhs[:, None],
        lower=False, unit_diagonal=True,
    )[:, 0]  # z[q] = T_ord[pos[target], q]
    return z[pos]


def effects_avoiding(adjacency, order, blocked):
    """Total effects along paths avoiding the ``blocked`` node set.

    ``blocked`` is a (d,) bool mask. Severing a node's *outgoing* edges
    (its column of ``B``) removes exactly the paths that pass through
    it while leaving paths that merely end there; the mutilated graph
    keeps the same causal order, so the triangular solve applies
    unchanged.
    """
    b = jnp.where(blocked[None, :], 0.0, adjacency)
    return total_effects_impl(b, order)


def effects_through(adjacency, order, nodes):
    """Total effects along paths passing through the ``nodes`` set
    (complement of :func:`effects_avoiding`; zero diagonal)."""
    return total_effects_impl(adjacency, order) - effects_avoiding(
        adjacency, order, nodes
    )


def var_irf(b0, order, var_coefs, horizon: int):
    """Structural impulse responses of a VarLiNGAM fit.

    Args:
      b0:        (d, d) instantaneous adjacency (``theta_0``).
      order:     (d,) its causal order.
      var_coefs: (k, d, d) reduced-form VAR coefficient matrices
                 ``M_tau`` (``VarLiNGAM.var_coefs_`` /
                 ``RollingFit.var_coefs``).
      horizon:   static number of lag steps to propagate.

    Returns:
      (horizon + 1, d, d) responses: ``irf[h, i, j]`` is the change in
      ``x_{t+h, i}`` per unit shock to the structural noise ``e_{t, j}``
      — ``irf[0] = (I - B0)^{-1}`` (instantaneous total effects), later
      steps propagate through the reduced-form recursion
      ``Phi_h = sum_tau M_tau Phi_{h-tau}`` as one scan.
    """
    b0 = jnp.asarray(b0, jnp.float32)
    var_coefs = jnp.asarray(var_coefs, jnp.float32)
    d = b0.shape[0]
    k = var_coefs.shape[0]
    a0 = total_effects_impl(b0, order)
    eye = jnp.eye(d, dtype=b0.dtype)
    carry0 = jnp.concatenate(
        [eye[None], jnp.zeros((k - 1, d, d), b0.dtype)], axis=0
    )

    def step(carry, _):
        # carry[t] = Phi_{h-1-t}: newest reduced-form response first.
        phi = jnp.einsum("tij,tjk->ik", var_coefs, carry)
        return jnp.concatenate([phi[None], carry[:-1]], axis=0), phi

    _, phis = jax.lax.scan(step, carry0, None, length=horizon)
    phis = jnp.concatenate([eye[None], phis], axis=0)
    return phis @ a0


def _effects_post(result: api.FitResult):
    """In-trace per-resample hook for ``batched.bootstrap_fits_with``."""
    return total_effects_impl(result.adjacency, result.order)


@dataclasses.dataclass
class EffectCI:
    """Bootstrap confidence intervals over the total-effect matrix."""

    mean: np.ndarray    # (d, d) resample mean of T
    std: np.ndarray     # (d, d)
    lo: np.ndarray      # (d, d) lower percentile bound
    hi: np.ndarray      # (d, d) upper percentile bound
    level: float        # two-sided coverage level of [lo, hi]
    n_sampling: int

    def covers(self, true_effects) -> np.ndarray:
        """(d, d) bool: does [lo, hi] contain each true effect?"""
        t = np.asarray(true_effects)
        return (self.lo <= t) & (t <= self.hi)

    def significant_effects(self, min_abs: float = 0.0):
        """[(i, j, mean, lo, hi)] for off-diagonal effects whose CI
        excludes zero (and |mean| >= min_abs), sorted by |mean|."""
        d = self.mean.shape[0]
        sig = ((self.lo > 0) | (self.hi < 0)) & ~np.eye(d, dtype=bool)
        sig &= np.abs(self.mean) >= min_abs
        out = [
            (int(i), int(j), float(self.mean[i, j]),
             float(self.lo[i, j]), float(self.hi[i, j]))
            for i, j in np.argwhere(sig)
        ]
        return sorted(out, key=lambda t: -abs(t[2]))


def bootstrap_effects(
    x,
    n_sampling: int = 20,
    level: float = 0.9,
    seed: int = 0,
    config: Optional[api.FitConfig] = None,
) -> EffectCI:
    """Effect confidence intervals from one compiled bootstrap program.

    Every resample's refit *and* its total-effect triangular solve run
    inside the single ``bootstrap_fits_with`` program (same on-device
    index matrix as ``bootstrap_lingam``, so the resamples match);
    only the cheap percentile reduction happens host-side.
    """
    x = np.asarray(x, dtype=np.float32)
    m, _ = x.shape
    cfg = config or api.FitConfig(compaction="staged")
    indices = batched.resample_indices(seed, n_sampling, m)
    _, effs = batched.bootstrap_fits_with(x, indices, cfg, _effects_post)
    effs = np.asarray(effs)
    alpha = 0.5 * (1.0 - level)
    lo = np.quantile(effs, alpha, axis=0)
    hi = np.quantile(effs, 1.0 - alpha, axis=0)
    return EffectCI(
        mean=effs.mean(axis=0),
        std=effs.std(axis=0),
        lo=lo,
        hi=hi,
        level=level,
        n_sampling=n_sampling,
    )
