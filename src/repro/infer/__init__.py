"""Causal query & effect-inference subsystem.

Discovery produces a graph; this package *answers questions* with it.
Every entry point consumes the functional core's
:class:`~repro.core.api.FitResult` (or a streaming session's rolling
estimate) and stays jit/vmap-clean, so single queries, bootstrap
ensembles, and serving micro-batches all run as compiled device
programs:

  * :mod:`repro.infer.effects` — total-effect matrices ``(I - B)^-1``
    via triangular solve in causal order (never a dense inverse),
    path-specific effects, lag-propagated VAR impulse responses, and
    bootstrap effect confidence intervals.
  * :mod:`repro.infer.intervene` — do-operator graph surgery and
    interventional means/covariances derived from observational
    moments (including the streaming moment store — no row re-reads).
  * :mod:`repro.infer.rca` — root-cause attribution of anomalous
    samples by noise-term decomposition ``e = (I - B) x``, batched
    over samples with dispatch-routed sample slabs.
  * :mod:`repro.infer.query` — :class:`~repro.infer.query.QueryEngine`:
    admits Effect / Intervention / RCA requests against fitted or
    streaming graphs, buckets them by (shape, kind), and executes each
    bucket as one compiled device-parallel program
    (:meth:`repro.serve.engine.CausalDiscoveryEngine.query` is the
    serving-side entry).
"""

from .effects import (  # noqa: F401
    EffectCI,
    bootstrap_effects,
    effects_avoiding,
    effects_through,
    target_effects_row,
    total_effects,
    total_effects_impl,
    var_irf,
)
from .intervene import (  # noqa: F401
    do_arrays,
    interventional_from_state,
    interventional_moments,
    mutilate,
    noise_stats,
)
from .query import (  # noqa: F401
    EffectQuery,
    FittedGraph,
    InterventionQuery,
    QueryEngine,
    RCAQuery,
)
from .rca import (  # noqa: F401
    RCAResult,
    attribute,
    noise_scores_impl,
    noise_terms_impl,
)
