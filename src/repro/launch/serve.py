"""Serving launcher: batched prefill+decode with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --new-tokens 16
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import model as model_lib
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    params = model_lib.init_params(
        cfg, jax.random.key(args.seed), max_seq=args.max_seq
    )
    engine = ServeEngine(
        cfg, params, batch_size=args.batch, max_seq=args.max_seq,
        temperature=args.temperature, seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    pending = [
        Request(
            prompt=rng.integers(1, cfg.vocab_size, args.prompt_len).astype(
                np.int32
            ),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.requests)
    ]
    done = 0
    t0 = time.perf_counter()
    while pending:
        batch, pending = pending[: args.batch], pending[args.batch:]
        engine.generate(batch)
        done += len(batch)
        for r in batch:
            print(f"req[{done}] -> {r.out_tokens[:8]}...")
    dt = time.perf_counter() - t0
    total_tokens = args.requests * args.new_tokens
    print(f"{done} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
