import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``.lower().compile()`` must succeed against the production
meshes (16x16 single pod, 2x16x16 multi-pod) for every assigned
architecture x input shape, plus the paper's own LiNGAM workloads.
Outputs per-cell roofline inputs (FLOPs, bytes, collective bytes by kind,
memory analysis) to a JSON consumed by analysis/report.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun.json
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import roofline  # noqa: E402
from repro.configs.base import (  # noqa: E402
    SHAPES,
    get_arch,
    list_archs,
    supported_shapes,
)
from repro.dist import sharding as shd  # noqa: E402
from repro.launch.input_specs import input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.train.optimizer import AdamW  # noqa: E402
from repro.train.train_step import TrainState, init_state, make_train_step  # noqa: E402

# Gradient-accumulation settings for the big training cells (bounds
# activation memory; per-device microbatch stays ~1 sequence).
TRAIN_ACCUM = {
    "nemotron-4-340b": 4,
    "llama-3.2-vision-90b": 4,
    "jamba-v0.1-52b": 2,
}

# The paper's own workloads (see configs/lingam_workloads.py), run through
# the sharded causal-ordering scan (samples over data/pod, tiles over model).
from repro.configs.lingam_workloads import WORKLOADS  # noqa: E402

LINGAM_CELLS = [(w.name, w.m, w.d) for w in WORKLOADS.values()]


def _cost_analysis(lowered, compiled):
    try:
        c = compiled.cost_analysis()
        if c:
            return c
    except Exception:
        pass
    try:
        return lowered.cost_analysis() or {}
    except Exception:
        return {}


def _memory_analysis(compiled) -> Dict[str, Any]:
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        return {
            "argument_bytes": getattr(m, "argument_size_in_bytes", None),
            "output_bytes": getattr(m, "output_size_in_bytes", None),
            "temp_bytes": getattr(m, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                m, "generated_code_size_in_bytes", None
            ),
        }
    except Exception:
        return {}


def _arg_bytes_per_device(shardings_tree, shape_tree, mesh) -> int:
    """Analytic per-device argument bytes from shardings (CPU backend has no
    memory_analysis; this is exact for inputs)."""
    total = 0
    flat_s = jax.tree.leaves(
        shardings_tree, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
    )
    flat_t = jax.tree.leaves(shape_tree)
    for sh, leaf in zip(flat_s, flat_t):
        n = leaf.dtype.itemsize
        spec = sh.spec if hasattr(sh, "spec") else None
        for i, d in enumerate(leaf.shape):
            div = 1
            if spec is not None and i < len(spec) and spec[i] is not None:
                axes = spec[i] if isinstance(spec[i], tuple) else (spec[i],)
                for ax in axes:
                    div *= mesh.shape[ax]
            n *= -(-d // div)
        total += n
    return total


def lower_lm_cell(arch: str, shape_name: str, mesh, *, moe_impl="scatter",
                  accum_override=None, loss_chunk=None, remat=None,
                  cfg_overrides=None, seq_shard_kv=False):
    cfg = get_arch(arch)
    if loss_chunk is not None:
        cfg = cfg.replace(loss_chunk=loss_chunk)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)

    params_shape = jax.eval_shape(
        lambda: model_lib.init_params(
            cfg, jax.random.key(0), max_seq=shape.seq_len
        )
    )
    p_shard = shd.param_shardings(cfg, params_shape, mesh)

    if shape.kind == "train":
        opt = AdamW(state_dtype=cfg.optimizer_dtype)
        accum = accum_override or TRAIN_ACCUM.get(arch, 1)
        step = make_train_step(cfg, opt, accum_steps=accum, moe_impl=moe_impl)
        state_shape = jax.eval_shape(
            lambda: init_state(
                cfg, opt, jax.random.key(0), max_seq=shape.seq_len
            )
        )
        state_shard = TrainState(
            params=p_shard,
            opt=shd.opt_shardings(cfg, state_shape.opt, mesh, params_shape),
        )
        b_shard = shd.batch_spec(cfg, shape, mesh)
        fn = jax.jit(
            step,
            in_shardings=(state_shard, b_shard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )
        lowered = fn.lower(state_shape, specs)
        arg_bytes = _arg_bytes_per_device(
            (state_shard, b_shard), (state_shape, specs), mesh
        )
    elif shape.kind == "prefill":
        b_shard = shd.batch_spec(cfg, shape, mesh)
        cache_shape = jax.eval_shape(
            lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        d_spec = shd.decode_spec(cfg, shape, mesh, cache_shape)

        def pre(params, batch):
            return model_lib.prefill(
                cfg, params, batch["tokens"], max_seq=shape.seq_len,
                frontend=batch.get("frontend"), moe_impl=moe_impl,
            )

        fn = jax.jit(
            pre,
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, d_spec["caches"]),
        )
        lowered = fn.lower(params_shape, specs)
        arg_bytes = _arg_bytes_per_device(
            (p_shard, b_shard), (params_shape, specs), mesh
        )
    else:  # decode
        d_spec = shd.decode_spec(cfg, shape, mesh, specs["caches"],
                                 seq_shard_kv=seq_shard_kv)

        def dec(params, caches, token, pos, enc_out=None):
            return model_lib.decode_step(
                cfg, params, token, caches, pos, enc_out=enc_out,
                moe_impl=moe_impl,
            )

        args = [params_shape, specs["caches"], specs["token"], specs["pos"]]
        in_sh = [p_shard, d_spec["caches"], d_spec["token"], d_spec["pos"]]
        if "enc_out" in specs:
            args.append(specs["enc_out"])
            in_sh.append(d_spec["enc_out"])
        fn = jax.jit(
            dec,
            in_shardings=tuple(in_sh),
            out_shardings=(None, d_spec["caches"]),
            donate_argnums=(1,),
        )
        lowered = fn.lower(*args)
        arg_bytes = _arg_bytes_per_device(
            tuple(in_sh), tuple(args), mesh
        )

    counts = roofline.count_params(cfg, params_shape)
    mf = roofline.model_flops(cfg, shape, counts["total"], counts["active"])
    return lowered, {"params": counts, "model_flops": mf,
                     "arg_bytes_per_dev": arg_bytes}


def lower_lingam_cell(m: int, d: int, mesh):
    from repro.core.sharded import make_sharded_causal_order

    sample_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    fn, m_pad, d_pad = make_sharded_causal_order(
        mesh, m, d, sample_axes=sample_axes, chunk=512
    )
    x_sds = jax.ShapeDtypeStruct((m_pad, d_pad), jnp.float32)
    with mesh:
        lowered = fn.lower(x_sds)
    # "model FLOPs" for LiNGAM: d ordering steps, each = the correlation
    # matmul (2*m*d^2) + ~14 flops per (pair, sample) for residual+moments.
    mf = float(d) * (2.0 * m * d + 14.0 * m * d) * d
    arg_bytes = 4 * m_pad * d_pad // (
        mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    )
    return lowered, {
        "params": {"total": float(d * d), "active": float(d * d)},
        "model_flops": mf,
        "arg_bytes_per_dev": arg_bytes,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             moe_impl="scatter", **kw) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.size
    t0 = time.time()
    if arch.startswith(("lingam", "varlingam")):
        m, d = next((m, d) for name, m, d in LINGAM_CELLS if name == arch)
        lowered, aux = lower_lingam_cell(m, d, mesh)
    else:
        with mesh:
            lowered, aux = lower_lm_cell(
                arch, shape_name, mesh, moe_impl=moe_impl, **kw
            )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = _cost_analysis(lowered, compiled)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = roofline.collective_bytes(compiled.as_text())
    coll_total = float(sum(coll.values()))
    terms = roofline.roofline_terms(flops_dev, bytes_dev, coll_total)
    mem = _memory_analysis(compiled)

    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "flops_per_dev": flops_dev,
        "bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll,
        "collective_total_per_dev": coll_total,
        "terms": terms,
        "model_flops": aux["model_flops"],
        "model_flops_per_dev": aux["model_flops"] / chips,
        "useful_flops_ratio": (
            aux["model_flops"] / chips / flops_dev if flops_dev else None
        ),
        "params_total": aux["params"]["total"],
        "params_active": aux["params"]["active"],
        "arg_bytes_per_dev": aux["arg_bytes_per_dev"],
        "memory_analysis": mem,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "moe_impl": moe_impl,
    }
    print(
        f"[dryrun] {arch:24s} {shape_name:12s} {mesh_kind:8s} "
        f"compile={t_compile:6.1f}s flops/dev={flops_dev:.3e} "
        f"bytes/dev={bytes_dev:.3e} coll/dev={coll_total:.3e} "
        f"dominant={terms['dominant']}"
    )
    return out


def all_cells():
    cells = []
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape_name in supported_shapes(cfg):
            cells.append((arch, shape_name))
    for name, _, _ in LINGAM_CELLS:
        cells.append((name, "ordering"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default="scatter")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch, "--arch required without --all"
        shapes = [args.shape] if args.shape else [
            s for s in (supported_shapes(get_arch(args.arch))
                        if not args.arch.startswith(("lingam", "varlingam"))
                        else ["ordering"])
        ]
        cells = [(args.arch, s) for s in shapes]

    results = []
    if args.out and args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("moe_impl", "scatter"))
            for r in results}
    failures = []
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            key = (arch, shape_name, mesh_kind, args.moe_impl)
            if key in done:
                continue
            try:
                results.append(
                    run_cell(arch, shape_name, mesh_kind,
                             moe_impl=args.moe_impl)
                )
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape_name, mesh_kind, str(e)))
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"[dryrun] {len(results)} cells ok, {len(failures)} failed")
    for f_ in failures:
        print("  FAILED:", f_)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
