"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 200 --smoke --ckpt-dir /tmp/ckpt

On a real TPU pod each host runs this under the same flags and
`jax.distributed.initialize()` wires the mesh; on this CPU container
`--smoke` runs the reduced config on one device end-to-end (the multi-host
path is exercised structurally by the dry-run). XLA flags below enable
compute/communication overlap (latency-hiding scheduler + async
collectives) — the §Perf overlap posture.
"""

import os

_OVERLAP_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)
if "TPU_NAME" in os.environ or os.environ.get("REPRO_TPU", "0") == "1":
    os.environ["XLA_FLAGS"] = (
        _OVERLAP_FLAGS + os.environ.get("XLA_FLAGS", "")
    )

import argparse  # noqa: E402
import logging  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import SHAPES, ShapeConfig, get_arch  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train.optimizer import AdamW, cosine_warmup  # noqa: E402
from repro.train.trainer import Trainer  # noqa: E402
from repro.train.train_step import init_state  # noqa: E402

logging.basicConfig(level=logging.INFO, format="%(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (real pods)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch(args.arch, smoke=args.smoke)
    if args.smoke:
        shape = ShapeConfig("smoke", "train", 128, 8)
        mesh = None
        shardings = None
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        opt = AdamW(state_dtype=cfg.optimizer_dtype)
        state_shape = jax.eval_shape(
            lambda: init_state(cfg, opt, jax.random.key(args.seed),
                               max_seq=shape.seq_len)
        )
        from repro.train.train_step import TrainState

        p_sh = shd.param_shardings(cfg, state_shape.params, mesh)
        shardings = {
            "state": TrainState(
                params=p_sh,
                opt=shd.opt_shardings(cfg, state_shape.opt, mesh,
                                      state_shape.params),
            ),
            "batch": shd.batch_spec(cfg, shape, mesh),
        }

    opt = AdamW(
        lr=cosine_warmup(args.lr, warmup=max(args.steps // 20, 1),
                         total=args.steps),
        state_dtype=cfg.optimizer_dtype,
    )
    trainer = Trainer(
        cfg, shape, optimizer=opt, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, accum_steps=args.accum,
        seed=args.seed, mesh=mesh, shardings=shardings,
    )
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        state, step, losses = trainer.train(n_steps=args.steps)
    print(f"done: step={step} loss {losses[0]:.4f} -> {losses[-1]:.4f}")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
