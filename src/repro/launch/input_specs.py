"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns a dict matching exactly what
``train_step`` / ``prefill`` / ``decode_step`` consume, with no device
allocation. ``make_host_batch`` materializes the same shapes with real
numbers for smoke tests and the example drivers (frontend stubs included:
audio frames / vision patch embeddings arrive as precomputed embeddings).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as model_lib


def _frontend_shape(cfg: ArchConfig, batch: int):
    return (batch, cfg.n_frontend_tokens, cfg.d_model)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        spec = {
            "tokens": sds((b, s), i32),
            "labels": sds((b, s), i32),
        }
        if cfg.family in ("audio", "vlm"):
            spec["frontend"] = sds(_frontend_shape(cfg, b), f32)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": sds((b, s), i32)}
        if cfg.family in ("audio", "vlm"):
            spec["frontend"] = sds(_frontend_shape(cfg, b), f32)
        return spec
    if shape.kind == "decode":
        # eval_shape: NO allocation (a 32k x 128 cache is tens of GB)
        cache_spec = jax.eval_shape(
            lambda: model_lib.init_cache(cfg, b, s)
        )
        spec = {
            "token": sds((b, 1), i32),
            "caches": cache_spec,
            "pos": sds((), i32),
        }
        if cfg.family in ("audio", "vlm"):
            spec["enc_out"] = sds(_frontend_shape(cfg, b), f32)
        return spec
    raise ValueError(shape.kind)


def make_host_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
    """Materialize input_specs with real host data (for smoke/examples)."""
    rng = np.random.default_rng(seed)
    spec = input_specs(cfg, shape)

    def fill(s):
        if s.dtype == jnp.int32:
            if s.shape == ():
                return jnp.int32(min(16, shape.seq_len - 1))
            return jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=s.shape), jnp.int32
            )
        return jnp.asarray(rng.normal(size=s.shape) * 0.02, s.dtype)

    return jax.tree.map(fill, spec)
