"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and then calls these.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_from_spec(spec):
    """Build a mesh from a ((axis_name, size), ...) spec.

    The canonical constructor for :class:`repro.core.api.Partition.mesh`
    specs — e.g. ``(("data", 4), ("model", 2))`` on >= 8 devices. A 1 x 1
    spec is valid on a single device (the mesh plan's degenerate case).
    """
    names = tuple(a for a, _ in spec)
    sizes = tuple(int(s) for _, s in spec)
    return jax.make_mesh(sizes, names)


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *, pod: int = 0):
    """Small mesh for subprocess integration tests."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
