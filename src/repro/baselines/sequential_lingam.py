"""Faithful sequential (numpy, pair-loop) DirectLiNGAM — the paper's CPU
baseline and the semantic reference for the parallel implementation.

This mirrors the paper's Algorithm 1 pseudocode literally: python loops over
(i, j) pairs, per-pair standardization, residual, entropy difference. The
parallel implementation in ``repro.core`` must produce the *exact same*
causal order on simulated data (paper Fig. 3); tests assert this.
"""

from __future__ import annotations

import numpy as np

K1 = 79.047
K2 = 7.4129
GAMMA = 0.37457


def _entropy(u: np.ndarray) -> float:
    """Hyvarinen max-entropy approximation for standardized u."""
    h_gauss = 0.5 * (1.0 + np.log(2.0 * np.pi))
    au = np.abs(u)
    logcosh = np.mean(au + np.log1p(np.exp(-2.0 * au)) - np.log(2.0))
    uexp = np.mean(u * np.exp(-0.5 * u * u))
    return h_gauss - K1 * (logcosh - GAMMA) ** 2 - K2 * uexp**2


def _residual(xi: np.ndarray, xj: np.ndarray) -> np.ndarray:
    """Residual of regressing xi on xj (ddof=0 moments)."""
    cov = np.mean(xi * xj) - np.mean(xi) * np.mean(xj)
    var = np.var(xj)
    return xi - (cov / max(var, 1e-12)) * xj


def _diff_mutual_info(xi_std, xj_std, ri_j, rj_i) -> float:
    sr_i = np.std(ri_j)
    sr_j = np.std(rj_i)
    return (_entropy(xj_std) + _entropy(ri_j / max(sr_i, 1e-12))) - (
        _entropy(xi_std) + _entropy(rj_i / max(sr_j, 1e-12))
    )


def search_causal_order(x: np.ndarray, u_idx: np.ndarray) -> int:
    """Algorithm 1: return the most exogenous variable among ``u_idx``."""
    mu = x[:, u_idx].mean(axis=0)
    sd = x[:, u_idx].std(axis=0)
    x_std = (x[:, u_idx] - mu) / np.maximum(sd, 1e-12)
    k_list = np.zeros(len(u_idx))
    for a, i in enumerate(u_idx):
        k = 0.0
        for b, j in enumerate(u_idx):
            if i == j:
                continue
            xi_std = x_std[:, a]
            xj_std = x_std[:, b]
            ri_j = _residual(xi_std, xj_std)
            rj_i = _residual(xj_std, xi_std)
            mi_diff = _diff_mutual_info(xi_std, xj_std, ri_j, rj_i)
            k += min(0.0, mi_diff) ** 2
        k_list[a] = -k
    return int(u_idx[int(np.argmax(k_list))])


def causal_order_sequential(x: np.ndarray) -> np.ndarray:
    """Full sequential ordering loop (the 96%-of-runtime procedure)."""
    x = np.array(x, dtype=np.float64, copy=True)
    d = x.shape[1]
    u_idx = list(range(d))
    order = []
    for _ in range(d):
        root = search_causal_order(x, np.array(u_idx))
        for i in u_idx:
            if i != root:
                x[:, i] = _residual(x[:, i], x[:, root])
        u_idx.remove(root)
        order.append(root)
    return np.array(order, dtype=np.int64)


def ols_adjacency_sequential(x: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Per-variable OLS on causal predecessors (numpy lstsq)."""
    x = np.asarray(x, dtype=np.float64)
    d = x.shape[1]
    b = np.zeros((d, d))
    for p, i in enumerate(order):
        preds = order[:p]
        if len(preds) == 0:
            continue
        zp = x[:, preds] - x[:, preds].mean(axis=0)
        yi = x[:, i] - x[:, i].mean()
        coef, *_ = np.linalg.lstsq(zp, yi, rcond=None)
        b[i, preds] = coef
    return b


def fit_sequential(x: np.ndarray):
    order = causal_order_sequential(x)
    b = ols_adjacency_sequential(x, order)
    return order, b
