"""ICA-LiNGAM (Shimizu et al., 2006) — the original LiNGAM estimator.

The paper's DirectLiNGAM is the successor of this classic algorithm; it is
implemented here as the in-family baseline ("the ideas presented are
easily applicable to other LiNGAM variants", paper §1):

  1. FastICA (deflation, logcosh contrast — implemented in JAX) recovers
     W s.t. s = W x with independent non-Gaussian sources.
  2. Row-permute W so its diagonal is dominant (greedy max-|w|/cost
     assignment), scale rows to unit diagonal -> W'.
  3. B = I - W'; permute variables to the closest strictly-lower-
     triangular form (greedy upper-mass minimization) -> causal order.
  4. Prune with the same OLS/adaptive-lasso machinery as DirectLiNGAM.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning


def _whiten(x):
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    cov = (xc.T @ xc) / x.shape[0]
    vals, vecs = jnp.linalg.eigh(cov)
    vals = jnp.maximum(vals, 1e-8)
    k = vecs @ jnp.diag(vals**-0.5) @ vecs.T
    return xc @ k, k


def fastica(x, n_steps: int = 200, seed: int = 0):
    """Deflation FastICA with logcosh nonlinearity. x: (m, d) -> W (d, d)
    (unmixing in whitened space composed with the whitening matrix)."""
    m, d = x.shape
    z, k = _whiten(jnp.asarray(x, jnp.float32))
    key = jax.random.key(seed)
    w_init = jax.random.normal(key, (d, d), jnp.float32)

    def one_unit(carry, i):
        w_done = carry  # (d, d) rows already found (zeros beyond i)
        w = w_init[i]
        w = w / jnp.linalg.norm(w)

        def body(_, w):
            wx = z @ w  # (m,)
            g = jnp.tanh(wx)
            gp = 1.0 - g * g
            w_new = (z.T @ g) / m - jnp.mean(gp) * w
            # Gram-Schmidt against already-extracted rows
            proj = w_done.T @ (w_done @ w_new)
            w_new = w_new - proj
            return w_new / jnp.maximum(jnp.linalg.norm(w_new), 1e-9)

        w = jax.lax.fori_loop(0, n_steps, body, w)
        w_done = w_done.at[i].set(w)
        return w_done, None

    w_rows, _ = jax.lax.scan(
        one_unit, jnp.zeros((d, d), jnp.float32), jnp.arange(d)
    )
    return np.asarray(w_rows @ k.T)  # unmixing for raw (centered) x


def _permute_diag_dominant(w):
    """Hungarian assignment minimizing sum 1/|W_ii| (the standard
    ICA-LiNGAM row permutation, Shimizu et al. 2006 step 2)."""
    from scipy.optimize import linear_sum_assignment

    cost = 1.0 / np.maximum(np.abs(w), 1e-12)
    row_ind, col_ind = linear_sum_assignment(cost)
    perm = np.empty(w.shape[0], dtype=int)
    perm[col_ind] = row_ind
    return w[perm]


def _causal_order_from_b(b):
    """Greedy: repeatedly pick the row with smallest remaining in-mass."""
    d = b.shape[0]
    mass = np.abs(b).copy()
    remaining = list(range(d))
    order = []
    while remaining:
        sums = [mass[i, remaining].sum() for i in remaining]
        root = remaining[int(np.argmin(sums))]
        order.append(root)
        remaining.remove(root)
    return np.array(order)


@dataclasses.dataclass
class ICALiNGAM:
    n_steps: int = 200
    seed: int = 0
    prune_method: str = "ols"
    prune_threshold: float = 0.0

    causal_order_: Optional[np.ndarray] = None
    adjacency_: Optional[np.ndarray] = None

    def fit(self, x) -> "ICALiNGAM":
        x = np.asarray(x, dtype=np.float32)
        w = fastica(x, n_steps=self.n_steps, seed=self.seed)
        wp = _permute_diag_dominant(w)
        wp = wp / np.diag(wp)[:, None]
        b = np.eye(x.shape[1]) - wp
        order = _causal_order_from_b(b)
        badj = pruning.estimate_adjacency(
            jnp.asarray(x), jnp.asarray(order, jnp.int32),
            method=self.prune_method, threshold=self.prune_threshold,
        )
        self.causal_order_ = order
        self.adjacency_ = np.asarray(badj)
        return self
