"""NOTEARS (Zheng et al., 2018) in JAX — the continuous-optimization rival
the paper evaluates in §3.1.

    min_W  1/(2m) ||X - X W||_F^2 + lam ||W||_1
    s.t.   h(W) = tr(exp(W o W)) - d = 0

solved with the standard augmented-Lagrangian outer loop and an Adam inner
loop (jit'd, lax.fori_loop). The paper's point — that NOTEARS fails to
recover even simple layered DAGs (F1 ~ 0.79) — is reproduced by
benchmarks/bench_notears.py with the same lambda grid {0.001..0.1}.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _h_acyc(w):
    """tr(e^{W o W}) - d (differentiable acyclicity measure)."""
    d = w.shape[0]
    return jnp.trace(jax.scipy.linalg.expm(w * w)) - d


def _loss(w, x, lam, rho, alpha):
    m = x.shape[0]
    resid = x - x @ w
    mse = 0.5 / m * jnp.sum(resid * resid)
    h = _h_acyc(w)
    return mse + lam * jnp.sum(jnp.abs(w)) + 0.5 * rho * h * h + alpha * h


@functools.partial(jax.jit, static_argnames=("n_steps",))
def _inner_adam(w0, x, lam, rho, alpha, n_steps=300, lr=3e-2):
    grad_fn = jax.grad(_loss)

    def body(i, carry):
        w, m1, m2 = carry
        g = grad_fn(w, x, lam, rho, alpha)
        m1 = 0.9 * m1 + 0.1 * g
        m2 = 0.999 * m2 + 0.001 * g * g
        m1h = m1 / (1 - 0.9 ** (i + 1.0))
        m2h = m2 / (1 - 0.999 ** (i + 1.0))
        w = w - lr * m1h / (jnp.sqrt(m2h) + 1e-8)
        w = w * (1.0 - jnp.eye(w.shape[0], dtype=w.dtype))  # no self-loops
        return (w, m1, m2)

    w, _, _ = jax.lax.fori_loop(
        0, n_steps, body, (w0, jnp.zeros_like(w0), jnp.zeros_like(w0))
    )
    return w


def notears_fit(
    x,
    lam: float = 0.01,
    max_outer: int = 12,
    h_tol: float = 1e-8,
    rho_max: float = 1e16,
    w_threshold: float = 0.3,
    inner_steps: int = 400,
):
    """Returns the thresholded weighted adjacency W[j, i] (j -> i uses
    column convention X ~ X W; converted to the B[i, j] row convention of
    repro.core on return)."""
    x = jnp.asarray(x, jnp.float32)
    x = x - jnp.mean(x, axis=0, keepdims=True)
    d = x.shape[1]
    w = jnp.zeros((d, d), jnp.float32)
    rho, alpha, h = 1.0, 0.0, jnp.inf
    for _ in range(max_outer):
        while rho < rho_max:
            w_new = _inner_adam(w, x, lam, rho, alpha, n_steps=inner_steps)
            h_new = float(_h_acyc(w_new))
            if h_new > 0.25 * float(h if h != jnp.inf else 1e30):
                rho *= 10.0
            else:
                break
        w, h = w_new, h_new
        alpha += rho * h
        if h <= h_tol or rho >= rho_max:
            break
    w = np.array(w)
    w[np.abs(w) < w_threshold] = 0.0
    return w.T  # B[i, j]: effect of x_j on x_i


def notears_grid(x, lams=(0.001, 0.005, 0.01, 0.05, 0.1), **kw):
    """Paper §3.1 protocol: fit over the lambda grid, return all fits."""
    return {lam: notears_fit(x, lam=lam, **kw) for lam in lams}
