"""GOLEM (Ng et al., 2020) in JAX — Gaussian MLE structure learning with
soft acyclicity + sparsity penalties (discussed in paper §2.4).

    min_W  L(W; X) + lam1 ||W||_1 + lam2 h(W)
    L = d/2 log sum_i ||x_i - W^T x||^2 - log |det(I - W)|   (GOLEM-EV)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _h_acyc(w):
    d = w.shape[0]
    return jnp.trace(jax.scipy.linalg.expm(w * w)) - d


def _golem_loss(w, x, lam1, lam2):
    m, d = x.shape
    resid = x - x @ w
    likelihood = 0.5 * d * jnp.log(jnp.sum(resid * resid) / m)
    _, logdet = jnp.linalg.slogdet(jnp.eye(d) - w)
    return (
        likelihood
        - logdet
        + lam1 * jnp.sum(jnp.abs(w))
        + lam2 * _h_acyc(w)
    )


@functools.partial(jax.jit, static_argnames=("n_steps",))
def _fit_jit(x, lam1, lam2, n_steps, lr=1e-2):
    d = x.shape[1]
    w0 = jnp.zeros((d, d), jnp.float32)
    grad_fn = jax.grad(_golem_loss)

    def body(i, carry):
        w, m1, m2 = carry
        g = grad_fn(w, x, lam1, lam2)
        m1 = 0.9 * m1 + 0.1 * g
        m2 = 0.999 * m2 + 0.001 * g * g
        m1h = m1 / (1 - 0.9 ** (i + 1.0))
        m2h = m2 / (1 - 0.999 ** (i + 1.0))
        w = w - lr * m1h / (jnp.sqrt(m2h) + 1e-8)
        return (w * (1.0 - jnp.eye(d)), m1, m2)

    w, _, _ = jax.lax.fori_loop(
        0, n_steps, body, (w0, jnp.zeros_like(w0), jnp.zeros_like(w0))
    )
    return w


def golem_fit(x, lam1=2e-2, lam2=5.0, n_steps=3000, w_threshold=0.3):
    x = jnp.asarray(x, jnp.float32)
    x = x - jnp.mean(x, axis=0, keepdims=True)
    w = np.array(_fit_jit(x, lam1, lam2, n_steps))
    w[np.abs(w) < w_threshold] = 0.0
    return w.T  # B[i, j] convention
