"""Process-local counters, gauges, and latency histograms.

The metric surface the serving/stream/kernel layers record into::

    from repro.obs import metrics

    metrics.inc("serve.requests", 3)
    metrics.observe("serve.flush_s", 0.012, shape="(256,64)")
    metrics.gauge("stream.staleness_chunks", 4, sid="stream-0")

Series are keyed by (name, sorted labels). Histograms keep running
count/sum plus a bounded reservoir of recent values, from which
:func:`snapshot` derives p50/p95/p99 summaries. Exports:

  * :func:`snapshot` — a plain dict (JSON-safe) of every series.
  * :func:`to_prometheus_text` — the Prometheus text exposition format.

Recording is gated on :func:`repro.obs.trace.enabled` — one flag test
when telemetry is off — and guarded by a process lock when on, so
snapshots are stable under concurrent serving sessions. Spans feed the
same histograms (``span.<name>_s``) on exit.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

from . import trace

_RESERVOIR = 2048

_lock = threading.Lock()
_counters: Dict[Tuple, float] = {}
_gauges: Dict[Tuple, float] = {}
_hists: Dict[Tuple, "_Hist"] = {}


class _Hist:
    __slots__ = ("count", "total", "values", "_i")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.values: list = []
        self._i = 0

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if len(self.values) < _RESERVOIR:
            self.values.append(v)
        else:  # overwrite oldest (ring)
            self.values[self._i] = v
            self._i = (self._i + 1) % _RESERVOIR


def _key(name: str, labels: Dict[str, Any]) -> Tuple:
    return (name,) + tuple(sorted((k, str(v)) for k, v in labels.items()))


def inc(name: str, value: float = 1.0, **labels) -> None:
    """Add ``value`` to a monotonically increasing counter."""
    if not trace.enabled():
        return
    k = _key(name, labels)
    with _lock:
        _counters[k] = _counters.get(k, 0.0) + value


def gauge(name: str, value: float, **labels) -> None:
    """Set a last-value-wins gauge."""
    if not trace.enabled():
        return
    k = _key(name, labels)
    with _lock:
        _gauges[k] = float(value)


def observe(name: str, value: float, **labels) -> None:
    """Record one observation into a histogram series."""
    if not trace.enabled():
        return
    k = _key(name, labels)
    with _lock:
        h = _hists.get(k)
        if h is None:
            h = _hists[k] = _Hist()
        h.add(float(value))


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _series_name(key: Tuple) -> str:
    name, labels = key[0], key[1:]
    if not labels:
        return name
    body = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{body}}}"


def snapshot() -> Dict[str, Dict[str, Any]]:
    """Every series as a JSON-safe dict (histograms summarized)."""
    with _lock:
        counters = {_series_name(k): v for k, v in _counters.items()}
        gauges = {_series_name(k): v for k, v in _gauges.items()}
        hists = {}
        for k, h in _hists.items():
            vals = sorted(h.values)
            hists[_series_name(k)] = {
                "count": h.count,
                "sum": h.total,
                "p50": _percentile(vals, 0.50),
                "p95": _percentile(vals, 0.95),
                "p99": _percentile(vals, 0.99),
                "max": vals[-1] if vals else 0.0,
            }
    return {"counters": counters, "gauges": gauges, "histograms": hists}


def to_prometheus_text() -> str:
    """Prometheus text exposition of the current snapshot."""
    snap = snapshot()
    lines = []

    def emit(series: str, value) -> None:
        name = series.split("{", 1)[0]
        labels = series[len(name):]
        lines.append(f"{_sanitize(name)}{labels} {value}")

    for s, v in sorted(snap["counters"].items()):
        emit(s + "_total" if "{" not in s else _with_suffix(s, "_total"), v)
    for s, v in sorted(snap["gauges"].items()):
        emit(s, v)
    for s, h in sorted(snap["histograms"].items()):
        for stat in ("count", "sum", "p50", "p95", "p99", "max"):
            emit(_with_suffix(s, f"_{stat}"), h[stat])
    return "\n".join(lines) + ("\n" if lines else "")


def _sanitize(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _with_suffix(series: str, suffix: str) -> str:
    if "{" in series:
        name, rest = series.split("{", 1)
        return f"{name}{suffix}{{{rest}"
    return series + suffix


def reset() -> None:
    """Drop every recorded series (tests / fresh snapshots)."""
    with _lock:
        _counters.clear()
        _gauges.clear()
        _hists.clear()
