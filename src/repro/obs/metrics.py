"""Process-local counters, gauges, and latency histograms.

The metric surface the serving/stream/kernel layers record into::

    from repro.obs import metrics

    metrics.inc("serve.requests", 3)
    metrics.observe("serve.flush_s", 0.012, shape="(256,64)")
    metrics.gauge("stream.staleness_chunks", 4, sid="stream-0")

Series are keyed by (name, sorted labels). Histograms keep running
count/sum plus a bounded reservoir of recent values, from which
:func:`snapshot` derives p50/p95/p99 summaries. Exports:

  * :func:`snapshot` — a plain dict (JSON-safe) of every series.
  * :func:`to_prometheus_text` — the Prometheus text exposition format.

Recording is gated on :func:`repro.obs.trace.enabled` — one flag test
when telemetry is off — and guarded by a process lock when on, so
snapshots are stable under concurrent serving sessions. Spans feed the
same histograms (``span.<name>_s``) on exit.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

from . import trace

_RESERVOIR = 2048

_lock = threading.Lock()
_counters: Dict[Tuple, float] = {}
_gauges: Dict[Tuple, float] = {}
_hists: Dict[Tuple, "_Hist"] = {}


class _Hist:
    __slots__ = ("count", "total", "values", "_i")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.values: list = []
        self._i = 0

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if len(self.values) < _RESERVOIR:
            self.values.append(v)
        else:  # overwrite oldest (ring)
            self.values[self._i] = v
            self._i = (self._i + 1) % _RESERVOIR


def _key(name: str, labels: Dict[str, Any]) -> Tuple:
    return (name,) + tuple(sorted((k, str(v)) for k, v in labels.items()))


def inc(name: str, value: float = 1.0, **labels) -> None:
    """Add ``value`` to a monotonically increasing counter."""
    if not trace.enabled():
        return
    k = _key(name, labels)
    with _lock:
        _counters[k] = _counters.get(k, 0.0) + value


def gauge(name: str, value: float, **labels) -> None:
    """Set a last-value-wins gauge."""
    if not trace.enabled():
        return
    k = _key(name, labels)
    with _lock:
        _gauges[k] = float(value)


def observe(name: str, value: float, **labels) -> None:
    """Record one observation into a histogram series."""
    if not trace.enabled():
        return
    k = _key(name, labels)
    with _lock:
        h = _hists.get(k)
        if h is None:
            h = _hists[k] = _Hist()
        h.add(float(value))


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _series_name(key: Tuple) -> str:
    name, labels = key[0], key[1:]
    if not labels:
        return name
    body = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{body}}}"


def snapshot() -> Dict[str, Dict[str, Any]]:
    """Every series as a JSON-safe dict (histograms summarized)."""
    with _lock:
        counters = {_series_name(k): v for k, v in _counters.items()}
        gauges = {_series_name(k): v for k, v in _gauges.items()}
        hists = {}
        for k, h in _hists.items():
            vals = sorted(h.values)
            hists[_series_name(k)] = {
                "count": h.count,
                "sum": h.total,
                "p50": _percentile(vals, 0.50),
                "p95": _percentile(vals, 0.95),
                "p99": _percentile(vals, 0.99),
                "max": vals[-1] if vals else 0.0,
            }
    return {"counters": counters, "gauges": gauges, "histograms": hists}


def _escape_label_value(v: str) -> str:
    """Prometheus text-format label-value escaping: backslash, double
    quote, and newline must be escaped or the line breaks the scrape."""
    return (
        v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
    )


def _label_body(labels: Tuple) -> str:
    if not labels:
        return ""
    body = ",".join(
        f'{_sanitize(k)}="{_escape_label_value(v)}"' for k, v in labels
    )
    return "{" + body + "}"


def to_prometheus_text() -> str:
    """Prometheus text exposition of the current state.

    Emits one ``# HELP`` / ``# TYPE`` header per metric family (counter
    families carry the ``_total`` suffix; histogram summaries surface as
    per-stat gauge families) and escapes label values (backslash, quote,
    newline), so the output scrapes cleanly even when labels carry
    shapes, paths, or error strings.
    """
    with _lock:
        counters = dict(_counters)
        gauges = dict(_gauges)
        hists = {
            k: {"count": h.count, "sum": h.total,
                "values": sorted(h.values)}
            for k, h in _hists.items()
        }

    # family name -> (type, [(label_tuple, value)])
    families: Dict[str, Tuple[str, list]] = {}

    def add(family: str, kind: str, labels: Tuple, value) -> None:
        fam = families.setdefault(family, (kind, []))
        fam[1].append((labels, value))

    for (name, *labels), v in counters.items():
        add(_sanitize(name) + "_total", "counter", tuple(labels), v)
    for (name, *labels), v in gauges.items():
        add(_sanitize(name), "gauge", tuple(labels), v)
    for (name, *labels), h in hists.items():
        vals = h["values"]
        stats = {
            "count": h["count"], "sum": h["sum"],
            "p50": _percentile(vals, 0.50), "p95": _percentile(vals, 0.95),
            "p99": _percentile(vals, 0.99),
            "max": vals[-1] if vals else 0.0,
        }
        for stat, value in stats.items():
            add(f"{_sanitize(name)}_{stat}", "gauge", tuple(labels), value)

    lines = []
    for family in sorted(families):
        kind, rows = families[family]
        lines.append(f"# HELP {family} repro.obs {kind} series {family}")
        lines.append(f"# TYPE {family} {kind}")
        for labels, value in sorted(rows, key=lambda r: r[0]):
            lines.append(f"{family}{_label_body(labels)} {value}")
    return "\n".join(lines) + ("\n" if lines else "")


def _sanitize(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def reset() -> None:
    """Drop every recorded series (tests / fresh snapshots)."""
    with _lock:
        _counters.clear()
        _gauges.clear()
        _hists.clear()
