"""Bounded append-only ring: the shared history container.

Long-lived serving processes accumulate history — flush errors, drift
alerts, span roots — and a pathological session must not be able to
grow those lists without bound. :class:`BoundedRing` is the one
container the obs/serve/stream layers share for that: a deque-backed
ring that keeps the newest ``maxlen`` items, counts what it evicted
(``dropped``), and quacks enough like a list (len / iter / index /
bool) that call sites written against plain lists keep working.
"""

from __future__ import annotations

import collections
from typing import Iterable, Iterator, List, TypeVar

T = TypeVar("T")


class BoundedRing:
    """Fixed-capacity newest-wins ring with an eviction counter."""

    __slots__ = ("_items", "dropped")

    def __init__(self, maxlen: int, items: Iterable = ()):
        if maxlen < 1:
            raise ValueError(f"maxlen must be >= 1, got {maxlen}")
        self._items: collections.deque = collections.deque(
            items, maxlen=maxlen
        )
        self.dropped = 0  # items evicted to stay within maxlen

    @property
    def maxlen(self) -> int:
        return self._items.maxlen  # type: ignore[return-value]

    def append(self, item) -> None:
        if len(self._items) == self._items.maxlen:
            self.dropped += 1
        self._items.append(item)

    def extend(self, items: Iterable) -> None:
        for item in items:
            self.append(item)

    def clear(self) -> None:
        """Drop contents *and* the eviction count (a fresh window)."""
        self._items.clear()
        self.dropped = 0

    def drain(self) -> List:
        """Pop everything (oldest first) — the consume-once read."""
        out = list(self._items)
        self._items.clear()
        return out

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __iter__(self) -> Iterator:
        return iter(self._items)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._items)[i]
        return self._items[i]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"BoundedRing(maxlen={self.maxlen}, n={len(self)}, "
            f"dropped={self.dropped})"
        )
