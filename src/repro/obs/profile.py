"""Performance accounting: cost capture, memory watermarks, roofline
utilization, and profiler-correlated device traces.

This is the fourth telemetry primitive (after spans, metrics, and the
compile log): it answers *how close to the hardware* the compiled
programs run, not just how long they took.

  * **Cost capture** — :func:`call` routes a jitted entry point through
    the profiler: once per ``(op, shape-bucket, config-hash)`` signature
    it AOT-lowers the program and records ``cost_analysis()``
    FLOPs/bytes, ``memory_analysis()`` argument/output/temp watermarks,
    and per-collective operand bytes parsed from the optimized HLO
    (:func:`collective_bytes`). Signatures use the exact key scheme of
    :mod:`repro.obs.compile_log`, so cost rows and compile events join
    on ``(op, shape, config)``.
  * **Roofline utilization** — :func:`device_peaks` is a small registry
    of per-device peak FLOP/s and memory bandwidth (detected from
    ``jax.devices()[0].device_kind``; override with ``REPRO_PEAKS``).
    :func:`utilization` turns (flops, bytes, seconds) into achieved
    GFLOP/s, GB/s, arithmetic intensity, and fraction-of-roofline;
    every timed :func:`call` feeds these into ``obs.metrics`` gauges.
  * **Device-trace correlation** — :func:`device_trace` wraps
    ``jax.profiler.trace`` and mirrors host span names into device
    ``TraceAnnotation``s, so the host span tree and the device timeline
    line up in one Perfetto view.

Profiling is **off by default** — enable with :func:`enable` or
``REPRO_OBS_PROFILE=1``. Disabled, :func:`call` is a plain passthrough
(one flag test, no timing, no lowering), so results and compile counts
are bit-identical to un-instrumented runs — the same pinned guarantee
spans give. Enabled, calls are synchronous (``block_until_ready``) and
the first call per signature additionally AOT-compiles, so compile
events may double-fire; only the *disabled* state carries the
zero-delta pin.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import os
import re
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from . import compile_log, metrics, trace

_ENV_VAR = "REPRO_OBS_PROFILE"
_PEAKS_ENV = "REPRO_PEAKS"

_ENABLED = os.environ.get(_ENV_VAR, "").strip().lower() not in (
    "", "0", "false", "off",
)

_lock = threading.Lock()
_records: Dict[Tuple, "CostRecord"] = {}


def enable(on: bool = True) -> None:
    """Turn performance profiling on (cost capture + timed calls)."""
    global _ENABLED
    _ENABLED = bool(on)


def disable() -> None:
    enable(False)


def enabled() -> bool:
    return _ENABLED


def _trace_clean() -> bool:
    """True when no jax trace is active. Cost capture must never run
    mid-trace: lowering there would stage host work into someone else's
    program; inside a trace :func:`call` degrades to a plain call."""
    try:
        import jax

        return jax.core.trace_state_clean()
    except Exception:  # pragma: no cover - jax absent/ancient
        return True


# ---------------------------------------------------------------------------
# Device-peaks registry (roofline ceilings)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DevicePeaks:
    """Peak rates of one device kind — the roofline ceilings.

    ``flops_per_s`` is the dense fp32 (or bf16 where that is the native
    matmul rate) peak of one chip; ``hbm_bw`` its main-memory bandwidth
    in bytes/s; ``ici_bw`` the per-link interconnect bandwidth used for
    collective terms. Entries are nominal vendor numbers — utilization
    fractions are comparative, not certified.
    """

    name: str
    flops_per_s: float
    hbm_bw: float
    ici_bw: float


#: Substring-matched (against ``device_kind.lower()``) peak entries,
#: first match wins. The cpu entry is a deliberately round placeholder
#: for a ~2-core container — override with ``REPRO_PEAKS`` for real
#: host baselines.
PEAKS_TABLE: Tuple[Tuple[str, DevicePeaks], ...] = (
    ("v5 lite", DevicePeaks("tpu-v5e", 197e12, 819e9, 50e9)),
    ("v5e", DevicePeaks("tpu-v5e", 197e12, 819e9, 50e9)),
    ("v5p", DevicePeaks("tpu-v5p", 459e12, 2765e9, 100e9)),
    ("v4", DevicePeaks("tpu-v4", 275e12, 1228e9, 50e9)),
    ("v3", DevicePeaks("tpu-v3", 123e12, 900e9, 50e9)),
    ("h100", DevicePeaks("gpu-h100", 989e12, 3350e9, 450e9)),
    ("a100", DevicePeaks("gpu-a100", 312e12, 2039e9, 300e9)),
    ("gpu", DevicePeaks("gpu-generic", 100e12, 1000e9, 100e9)),
    ("cpu", DevicePeaks("cpu-generic", 100e9, 20e9, 10e9)),
)

_FALLBACK_PEAKS = DevicePeaks("unknown", 100e9, 20e9, 10e9)


def device_peaks(kind: Optional[str] = None) -> DevicePeaks:
    """Roofline ceilings for ``kind`` (default: the process's device).

    ``REPRO_PEAKS`` overrides individual fields on top of the detected
    entry — ``REPRO_PEAKS="flops=3.2e12,hbm=80e9"`` calibrates a real
    host without code changes (keys: name/flops/hbm/ici).
    """
    if kind is None:
        try:
            import jax

            kind = jax.devices()[0].device_kind
        except Exception:  # pragma: no cover - jax must not be a hard dep
            kind = "unknown"
    low = str(kind).lower()
    base = _FALLBACK_PEAKS
    for token, peaks in PEAKS_TABLE:
        if token in low:
            base = peaks
            break
    env = os.environ.get(_PEAKS_ENV, "").strip()
    if not env:
        return base
    fields = {"name": base.name, "flops": base.flops_per_s,
              "hbm": base.hbm_bw, "ici": base.ici_bw}
    for part in env.split(","):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        k = k.strip().lower()
        if k == "name":
            fields["name"] = v.strip()
        elif k in fields:
            try:
                fields[k] = float(v)
            except ValueError:
                pass
    return DevicePeaks(
        name=str(fields["name"]), flops_per_s=float(fields["flops"]),
        hbm_bw=float(fields["hbm"]), ici_bw=float(fields["ici"]),
    )


def utilization(
    flops: float, bytes_accessed: float, seconds: float,
    peaks: Optional[DevicePeaks] = None,
) -> Dict[str, Any]:
    """Achieved rates and roofline fraction of one timed execution.

    ``roofline_frac`` is (roofline-bound seconds) / (measured seconds):
    the bound is ``max(flops/peak_flops, bytes/hbm_bw)``, so 1.0 means
    the kernel ran exactly at the ceiling its arithmetic intensity
    allows. Values above 1 flag a mis-calibrated peaks entry (cache
    effects on cpu commonly produce them) rather than magic hardware.
    """
    peaks = peaks or device_peaks()
    s = max(float(seconds), 1e-12)
    t_compute = flops / peaks.flops_per_s
    t_memory = bytes_accessed / peaks.hbm_bw
    bound_s = max(t_compute, t_memory)
    return {
        "gflops_per_s": flops / s / 1e9,
        "gbytes_per_s": bytes_accessed / s / 1e9,
        "intensity": flops / max(bytes_accessed, 1.0),
        "roofline_frac": bound_s / s,
        "bound": "compute" if t_compute >= t_memory else "memory",
        "peaks": peaks.name,
    }


# ---------------------------------------------------------------------------
# Analytic cost model (dispatch-time estimates + the test oracle)
# ---------------------------------------------------------------------------

#: Flops per (pair, sample) element of the moment kernels' integrands:
#: residual u = x_i - c_ij * x_j (2), log cosh as |u| + log1p(exp(-2|u|))
#: - log2 (~19 counting each transcendental as 8), u * exp(-u^2/2)
#: (~12), two fp32 accumulates (2) — 35 total. A *model*, not an HLO
#: count: it makes analytic and measured rows comparable, and the
#: roofline-oracle test pins the arithmetic below against it.
PAIR_FLOPS = 35


def analytic_cost(op: str, shape) -> Optional[Dict[str, float]]:
    """Model FLOPs/bytes for one registered moment op at one shape.

    Byte counts are the streamed-traffic model (fp32): each input slab
    read once per use, both (d, d)-family moment outputs written once —
    the same working-set accounting as ``registry.vmem_bytes``. Returns
    None for ops without a model.
    """
    try:
        dims = tuple(int(s) for s in shape)
    except TypeError:
        return None
    if op == "pairwise_moments" and len(dims) == 2:
        m, d = dims
        flops = float(PAIR_FLOPS) * d * d * m
        nbytes = 4.0 * (2 * m * d + 2 * d * d)
    elif op in ("pairwise_moment_sums_rows", "fused_moment_sums") \
            and len(dims) == 3:
        tile, d, m = dims
        flops = float(PAIR_FLOPS) * tile * d * m
        nbytes = 4.0 * (m * tile + m * d + 2 * tile * d)
    elif op == "pairwise_moment_sums_chunked" and len(dims) == 2:
        m, d = dims
        flops = float(PAIR_FLOPS) * d * d * m
        nbytes = 4.0 * (2 * m * d + 2 * d * d)
    else:
        return None
    return {
        "flops": flops,
        "bytes": nbytes,
        "intensity": flops / max(nbytes, 1.0),
    }


# ---------------------------------------------------------------------------
# HLO collective-bytes parser (moved from analysis/roofline.py)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one shaped buffer: f32[128,256]  (layout braces optional)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_OPND_RE = re.compile(r"\(([^)]*)\)")


def _shape_bytes(text: str) -> int:
    """Sum bytes over all shaped buffers appearing in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes (per device) from optimized HLO.

    ``cost_analysis()`` does not attribute collective traffic, so this
    parses the post-partitioning module (``compiled.as_text()``): build
    a name->bytes table from every instruction's result shape, then sum
    the operand sizes of each all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute.
    """
    sizes: Dict[str, int] = {}
    pending = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type = shapes in rhs before the opcode's '('.
        head = rhs.split("(", 1)[0]
        sizes[name.lstrip("%")] = _shape_bytes(head)
        for kind in _COLLECTIVES:
            # match opcode token, e.g. " all-reduce(" or "all-reduce-start("
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                pending.append((kind, rhs))
                break

    out = {k: 0 for k in _COLLECTIVES}
    for kind, rhs in pending:
        opnds = _OPND_RE.search(rhs)
        got = 0
        if opnds:
            for op in opnds.group(1).split(","):
                op = op.strip().lstrip("%")
                # operands may be written 'f32[..] %name' or just '%name'
                tok = op.split(" ")[-1].lstrip("%")
                if tok in sizes:
                    got += sizes[tok]
                else:
                    got += _shape_bytes(op)
        if got == 0:
            got = _shape_bytes(rhs.split("(", 1)[0])  # fallback: result
        out[kind] += got
    return out


# ---------------------------------------------------------------------------
# Cost store
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CostRecord:
    """One program signature's captured costs + execution statistics."""

    op: str
    shape: Tuple[int, ...]
    config: str                      # compile_log.config_hash token
    flops: float = 0.0               # per-execution, from cost_analysis
    bytes_accessed: float = 0.0
    arg_bytes: int = 0               # memory_analysis watermarks
    out_bytes: int = 0
    temp_bytes: int = 0
    collectives: Dict[str, int] = dataclasses.field(default_factory=dict)
    source: str = "measured"         # "measured" | "analytic" | "unavailable"
    calls: int = 0
    total_s: float = 0.0
    best_s: float = math.inf

    def row(self, peaks: Optional[DevicePeaks] = None) -> Dict[str, Any]:
        """JSON-safe row with utilization derived at the best latency."""
        out: Dict[str, Any] = {
            "op": self.op,
            "shape": list(self.shape),
            "config": self.config,
            "source": self.source,
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "arg_bytes": self.arg_bytes,
            "out_bytes": self.out_bytes,
            "temp_bytes": self.temp_bytes,
            "collective_bytes": dict(self.collectives),
            "calls": self.calls,
            "total_s": self.total_s,
            "best_s": self.best_s if self.calls else 0.0,
        }
        if self.calls and (self.flops or self.bytes_accessed):
            out.update(utilization(
                self.flops, self.bytes_accessed, self.best_s, peaks
            ))
        return out


def _key(op: str, shape, config) -> Tuple:
    # The exact compile_log key scheme: cost rows join compile events.
    return (op, compile_log._shape_key(shape), compile_log.config_hash(config))


def _capture(fn, args, kwargs, op: str, shape, config) -> CostRecord:
    rec = CostRecord(
        op=op,
        shape=compile_log._shape_key(shape),
        config=compile_log.config_hash(config),
    )
    compiled = None
    try:
        compiled = fn.lower(*args, **kwargs).compile()
    except Exception:
        compiled = None
    if compiled is None:
        a = analytic_cost(op, shape)
        if a is not None:
            rec.flops = a["flops"]
            rec.bytes_accessed = a["bytes"]
            rec.source = "analytic"
        else:
            rec.source = "unavailable"
        return rec
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # one entry per executable
            cost = cost[0] if cost else {}
        rec.flops = float(cost.get("flops", 0.0) or 0.0)
        rec.bytes_accessed = float(cost.get("bytes accessed", 0.0) or 0.0)
    except Exception:
        a = analytic_cost(op, shape)
        if a is not None:
            rec.flops, rec.bytes_accessed = a["flops"], a["bytes"]
            rec.source = "analytic"
    try:
        mem = compiled.memory_analysis()
        rec.arg_bytes = int(getattr(mem, "argument_size_in_bytes", 0) or 0)
        rec.out_bytes = int(getattr(mem, "output_size_in_bytes", 0) or 0)
        rec.temp_bytes = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
    except Exception:
        pass
    try:
        coll = collective_bytes(compiled.as_text())
        rec.collectives = {k: v for k, v in coll.items() if v}
    except Exception:
        pass
    return rec


def call(fn, *args, op: str, shape=None, config=None, **kwargs):
    """Route one jitted entry-point call through the profiler.

    Disabled (the default), this is ``fn(*args, **kwargs)`` — no timing,
    no lowering, bit-identical results and compile counts. Enabled, the
    first call per ``(op, shape-bucket, config-hash)`` captures costs
    via the AOT path (:func:`CostRecord`), then every call is timed
    synchronously and folded into the record plus ``obs.metrics``
    gauges. Mid-trace calls always pass straight through.
    """
    if not _ENABLED or not _trace_clean():
        return fn(*args, **kwargs)
    key = _key(op, shape, config)
    with _lock:
        rec = _records.get(key)
    if rec is None:
        rec = _capture(fn, args, kwargs, op, shape, config)
        with _lock:
            rec = _records.setdefault(key, rec)
    import jax

    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    with _lock:
        rec.calls += 1
        rec.total_s += dt
        rec.best_s = min(rec.best_s, dt)
    metrics.observe(f"profile.{op}_s", dt)
    if rec.flops or rec.bytes_accessed:
        u = utilization(rec.flops, rec.bytes_accessed, dt)
        metrics.gauge("profile.gflops_per_s", u["gflops_per_s"], op=op)
        metrics.gauge("profile.gbytes_per_s", u["gbytes_per_s"], op=op)
        metrics.gauge("profile.roofline_frac", u["roofline_frac"], op=op)
    if rec.temp_bytes:
        metrics.gauge("profile.temp_bytes", rec.temp_bytes, op=op)
    return out


def note_plan(op: str, shape, *, variant: str, source: str,
              vmem_model_bytes: int = 0) -> None:
    """Record a dispatch decision's analytic cost as gauges.

    Called from ``kernels.tune.registry.dispatch`` (trace time, once per
    compile): the plan's modelled arithmetic intensity and VMEM working
    set become queryable next to the measured records, so a plan whose
    model disagrees with captured ``temp_bytes`` is visible.
    """
    if not _ENABLED:
        return
    a = analytic_cost(op, shape)
    if a is not None:
        metrics.gauge("profile.plan_intensity", a["intensity"],
                      op=op, variant=variant, source=source)
        metrics.gauge("profile.plan_flops", a["flops"],
                      op=op, variant=variant, source=source)
    if vmem_model_bytes:
        metrics.gauge("profile.plan_vmem_bytes", vmem_model_bytes,
                      op=op, variant=variant, source=source)


def records() -> List[CostRecord]:
    """Every captured record (insertion order)."""
    with _lock:
        return list(_records.values())


def get(op: str, shape=None, config=None) -> Optional[CostRecord]:
    """The record for one signature, or None."""
    with _lock:
        return _records.get(_key(op, shape, config))


def snapshot() -> Dict[str, Any]:
    """JSON-safe dump: device peaks + one row per captured signature."""
    peaks = device_peaks()
    return {
        "device": dataclasses.asdict(peaks),
        "records": [r.row(peaks) for r in records()],
    }


def reset() -> None:
    """Drop every captured cost record (tests / fresh windows)."""
    with _lock:
        _records.clear()


# ---------------------------------------------------------------------------
# Device-trace correlation
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def device_trace(log_dir: str):
    """Correlated host+device profiling window.

    Wraps ``jax.profiler.trace(log_dir)`` (the Perfetto/XPlane device
    timeline) and, for its duration, mirrors every host span into a
    ``jax.profiler.TraceAnnotation`` of the same name — so the span tree
    rendered by ``obs.format_tree``/``write_chrome_trace`` and the
    device trace under ``log_dir`` align on names in one Perfetto view.
    No-op (plain yield) when profiling is disabled; span mirroring also
    requires spans, i.e. ``obs.enable()``.
    """
    if not _ENABLED:
        yield
        return
    import jax

    os.makedirs(log_dir, exist_ok=True)

    def hook(name: str):
        return jax.profiler.TraceAnnotation(name)

    trace.set_annotation_hook(hook)
    try:
        with jax.profiler.trace(log_dir):
            yield
    finally:
        trace.set_annotation_hook(None)
