"""Host-side tracing spans: nested, attributed, jit-safe.

A span times a region of *host* code::

    from repro import obs

    with obs.span("serve.flush", due=3):
        ...

Spans nest by the host call stack (one stack per thread) and carry
arbitrary attributes. They are **jit-safe by construction**: a span is
pure host bookkeeping — it never stages anything into a traced program,
so instrumented and uninstrumented runs produce bit-identical results
and identical compile counts. A span entered while a jax trace is being
built (e.g. around :func:`repro.kernels.tune.registry.dispatch`, which
runs at trace time) is tagged ``traced=True``: it measures trace/compile
construction, fires once per compile, and never re-executes in steady
state — compile-event accounting, not steady-state latency.

Telemetry is **off by default**. Enable with :func:`enable` or the
``REPRO_OBS=1`` environment variable; when disabled, :func:`span`
returns a shared no-op context manager (one flag test, no allocation),
so the instrumented hot paths cost nothing.

Completed root spans are kept in a bounded ring (newest last); render
them with :func:`format_tree`.
"""

from __future__ import annotations

import collections
import os
import threading
import time
from typing import Any, Dict, List, Optional

_ENV_VAR = "REPRO_OBS"

_ENABLED = os.environ.get(_ENV_VAR, "").strip().lower() not in (
    "", "0", "false", "off",
)

_MAX_ROOTS = 256

_lock = threading.Lock()
_roots: "collections.deque" = collections.deque(maxlen=_MAX_ROOTS)


class _Stack(threading.local):
    def __init__(self):
        self.spans: List["Span"] = []


_stack = _Stack()

# Optional span mirror: when set (by obs.profile.device_trace), every
# entered span calls it with the span name and enters the returned
# context manager — a jax.profiler.TraceAnnotation — so host spans show
# up on the device timeline under the same names. None (the default)
# costs one attribute read per span.
_annotation_hook = None


def set_annotation_hook(fn) -> None:
    """Install/clear (``None``) the span->device-annotation mirror."""
    global _annotation_hook
    _annotation_hook = fn


def enable(on: bool = True) -> None:
    """Turn telemetry on (spans + metrics). Off by default."""
    global _ENABLED
    _ENABLED = bool(on)


def disable() -> None:
    enable(False)


def enabled() -> bool:
    return _ENABLED


def _in_jax_trace() -> bool:
    """True while jax is building a trace (span executes at trace time)."""
    try:
        import jax

        return not jax.core.trace_state_clean()
    except Exception:  # pragma: no cover - jax absent/ancient
        return False


class Span:
    """One timed host region. Use via :func:`span`, not directly."""

    __slots__ = (
        "name", "attrs", "traced", "t0", "duration_s", "children", "_ann",
    )

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.traced = False
        self.t0 = 0.0
        self.duration_s = 0.0
        self.children: List["Span"] = []
        self._ann = None

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes mid-span."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.traced = _in_jax_trace()
        if _annotation_hook is not None:
            try:
                self._ann = _annotation_hook(self.name)
                self._ann.__enter__()
            except Exception:
                self._ann = None
        _stack.spans.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.duration_s = time.perf_counter() - self.t0
        if self._ann is not None:
            try:
                self._ann.__exit__(exc_type, exc, tb)
            finally:
                self._ann = None
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        stack = _stack.spans
        if stack and stack[-1] is self:
            stack.pop()
        if stack:
            stack[-1].children.append(self)
        else:
            with _lock:
                _roots.append(self)
        from . import metrics

        metrics.observe(f"span.{self.name}_s", self.duration_s)


class _NoopSpan:
    """Shared disabled-telemetry span: no allocation, no timing."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


_NOOP = _NoopSpan()


def span(name: str, **attrs):
    """A timed host-side span (no-op unless telemetry is enabled)."""
    if not _ENABLED:
        return _NOOP
    return Span(name, attrs)


def roots(last: Optional[int] = None) -> List[Span]:
    """Completed root spans, oldest first (bounded ring)."""
    with _lock:
        out = list(_roots)
    return out if last is None else out[-last:]


def reset() -> None:
    """Drop all recorded spans (the current thread's open stack too)."""
    with _lock:
        _roots.clear()
    _stack.spans.clear()


def _fmt_attrs(attrs: Dict[str, Any]) -> str:
    if not attrs:
        return ""
    body = ", ".join(f"{k}={v}" for k, v in attrs.items())
    return "  {" + body + "}"


def _fmt_span(s: Span, indent: int, lines: List[str]) -> None:
    ms = s.duration_s * 1e3
    tag = "  [trace]" if s.traced else ""
    lines.append(
        f"{'  ' * indent}{s.name}  {ms:.2f}ms{tag}{_fmt_attrs(s.attrs)}"
    )
    for c in s.children:
        _fmt_span(c, indent + 1, lines)


def format_tree(last: Optional[int] = None) -> str:
    """ASCII rendering of the recorded span trees."""
    lines: List[str] = []
    for s in roots(last):
        _fmt_span(s, 0, lines)
    return "\n".join(lines) if lines else "(no spans recorded)"


def to_chrome_trace(last: Optional[int] = None) -> Dict[str, Any]:
    """Finished span trees as Chrome/Perfetto trace-event JSON.

    Every span becomes one complete ("ph": "X") event with microsecond
    timestamps rebased to the earliest recorded root, so the file drops
    straight into ``chrome://tracing`` / https://ui.perfetto.dev.
    Span attributes land in ``args`` (stringified — trace viewers want
    flat JSON scalars); spans that ran at jax trace time keep their
    ``traced`` tag as the event category.
    """
    spans = roots(last)
    base = min((s.t0 for s in spans), default=0.0)
    events: List[Dict[str, Any]] = []

    def emit(s: Span) -> None:
        events.append({
            "name": s.name,
            "cat": "jax-trace" if s.traced else "host",
            "ph": "X",
            "ts": (s.t0 - base) * 1e6,
            "dur": s.duration_s * 1e6,
            "pid": 0,
            "tid": 0,
            "args": {k: str(v) for k, v in s.attrs.items()},
        })
        for c in s.children:
            emit(c)

    for s in spans:
        emit(s)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, last: Optional[int] = None) -> str:
    """Serialize :func:`to_chrome_trace` to ``path``; returns the path."""
    import json

    with open(path, "w") as f:
        json.dump(to_chrome_trace(last), f, indent=1)
    return path
