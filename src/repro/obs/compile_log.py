"""Compile-event accounting: which programs traced, how often, and why.

Every jit entry point in the library calls :func:`record` from inside
its trace body. Tracing runs the body as plain Python exactly once per
compile, so the call fires once per (shape, config) signature and never
again in steady state — the compile-count invariant the tests used to
pin with private per-module trace counters now lives behind one public
API, and a cache-miss storm (an engine recompiling per request) becomes
*queryable*::

    from repro.obs import compile_log

    before = compile_log.total("batched.fit_many")
    engine.run(requests)
    assert compile_log.total("batched.fit_many") == before  # warm cache

Events are keyed by ``(op, bucket_shape, config_hash)``; the recorder is
**always on** (unlike spans/metrics) because its only cost is a counter
update at trace time — steady-state execution never reaches it. Calling
it inside a trace body adds no operations to the traced program, so
results are bit-identical with or without telemetry.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

_MAX_EVENTS = 4096

_lock = threading.Lock()
_counts: collections.Counter = collections.Counter()
_events: "collections.deque" = collections.deque(maxlen=_MAX_EVENTS)


def _shape_key(shape) -> Tuple[int, ...]:
    if shape is None:
        return ()
    try:
        return tuple(int(s) for s in shape)
    except TypeError:
        return (int(shape),)


def config_hash(config) -> str:
    """Short stable token for a (hashable) config object."""
    if config is None:
        return "-"
    try:
        h = hash(config)
    except TypeError:
        h = hash(repr(config))
    return f"{h & 0xFFFFFFFF:08x}"


def record(op: str, shape=None, config=None, **attrs) -> None:
    """Log one compile event for ``op`` (call from inside a trace body)."""
    key = (op, _shape_key(shape), config_hash(config))
    with _lock:
        _counts[key] += 1
        _events.append({
            "op": op,
            "shape": key[1],
            "config": key[2],
            "time": time.time(),
            **attrs,
        })
    from . import metrics

    metrics.inc("compiles", op=op)


def counts(op: Optional[str] = None) -> Dict[Tuple, int]:
    """Compile counts keyed by (op, shape, config_hash)."""
    with _lock:
        items = dict(_counts)
    if op is None:
        return items
    return {k: v for k, v in items.items() if k[0] == op}


def total(op: Optional[str] = None) -> int:
    """Total compiles, optionally restricted to one op."""
    return sum(counts(op).values())


def by_op() -> Dict[str, int]:
    """Compile counts aggregated per op name."""
    out: Dict[str, int] = {}
    for (op, _, _), n in counts().items():
        out[op] = out.get(op, 0) + n
    return out


def events(op: Optional[str] = None) -> List[dict]:
    """The recent compile events, oldest first (bounded ring)."""
    with _lock:
        evs = list(_events)
    return evs if op is None else [e for e in evs if e["op"] == op]


def snapshot() -> Dict[str, Any]:
    """JSON-safe summary: per-op totals + per-signature counts."""
    return {
        "by_op": by_op(),
        "by_signature": {
            f"{op}:{list(shape)}:{cfg}": n
            for (op, shape, cfg), n in sorted(counts().items())
        },
    }


def reset() -> None:
    with _lock:
        _counts.clear()
        _events.clear()
