"""Unified telemetry for the AcceleratedLiNGAM stack.

Three jit-safe primitives, wired through every layer of the repo:

  * :mod:`repro.obs.trace` — nested host-side spans
    (``with obs.span("ordering.step", d=d): ...``). Off by default;
    enable with :func:`enable` or ``REPRO_OBS=1``. Spans never stage
    anything into traced programs: instrumented and uninstrumented runs
    produce bit-identical results and identical compile counts.
  * :mod:`repro.obs.metrics` — process-local counters / gauges /
    histograms with p50/p95/p99 summaries, exported via
    :func:`repro.obs.metrics.snapshot` or
    :func:`repro.obs.metrics.to_prometheus_text`.
  * :mod:`repro.obs.compile_log` — always-on compile-event accounting
    keyed by ``(op, shape, config_hash)``: every library jit entry point
    records its trace body, so recompile storms are queryable (and the
    test suite pins one-compile-per-bucket invariants through this
    public API instead of private counters).
  * :mod:`repro.obs.profile` — performance accounting on top of the
    other three: per-program ``cost_analysis()`` FLOPs/bytes and
    ``memory_analysis()`` watermarks keyed like the compile log,
    roofline utilization against the device-peaks registry, and
    ``device_trace()`` for span-annotated ``jax.profiler`` timelines.
    Off by default; enable with :func:`repro.obs.profile.enable` or
    ``REPRO_OBS_PROFILE=1``.

``analysis/regress.py`` closes the loop: it compares fresh benchmark
runs against the committed ``BENCH_*.json`` baselines (stamped with
:func:`provenance`) and fails CI on out-of-tolerance slowdowns.
"""

from __future__ import annotations

import time
from typing import Any, Dict

from . import compile_log, metrics, profile, ring, trace
from .ring import BoundedRing  # noqa: F401
from .trace import (  # noqa: F401  (re-exported convenience surface)
    enable,
    disable,
    enabled,
    format_tree,
    reset,
    roots,
    span,
    to_chrome_trace,
    write_chrome_trace,
)

__all__ = [
    "BoundedRing",
    "compile_log",
    "metrics",
    "profile",
    "ring",
    "trace",
    "enable",
    "disable",
    "enabled",
    "format_tree",
    "provenance",
    "reset",
    "reset_all",
    "roots",
    "span",
    "to_chrome_trace",
    "write_chrome_trace",
]


def reset_all() -> None:
    """Clear spans, metrics, the compile log, and cost records."""
    trace.reset()
    metrics.reset()
    compile_log.reset()
    profile.reset()


def provenance(repo_root: str = ".") -> Dict[str, Any]:
    """What produced this process's numbers: device, versions, git sha.

    Stamped into every ``BENCH_*.json`` artifact by ``benchmarks/run.py``
    so regression comparisons know what hardware/runtime produced the
    baseline they are diffing against.
    """
    out: Dict[str, Any] = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    try:
        import platform

        out["python"] = platform.python_version()
        out["hostname"] = platform.node()
    except Exception:  # pragma: no cover
        pass
    try:
        import jax

        out["jax_version"] = jax.__version__
        out["device_kind"] = jax.devices()[0].device_kind
        out["backend"] = jax.default_backend()
        out["platform"] = jax.devices()[0].platform
        out["n_devices"] = jax.device_count()
    except Exception:  # pragma: no cover - jax must not be a hard dep here
        out["jax_version"] = out["device_kind"] = "unknown"
    import os as _os
    import platform as _platform

    out["machine"] = _platform.machine()
    out["xla_flags"] = _os.environ.get("XLA_FLAGS", "")
    try:
        import subprocess

        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=repo_root, capture_output=True, text=True, timeout=5,
        )
        out["git_sha"] = sha.stdout.strip() if sha.returncode == 0 else "unknown"
    except Exception:  # pragma: no cover
        out["git_sha"] = "unknown"
    return out
