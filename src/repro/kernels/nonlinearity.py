"""The elementwise moment integrands of the Hyvarinen entropy terms.

``nonlinear_terms`` is the *single* definition of the two integrands
``(log cosh u, u exp(-u^2/2))`` shared by every consumer: the kernel
wrappers (:mod:`repro.kernels.ops`), the entropy measures
(:mod:`repro.core.measures`), and the mesh plan's column moments. It
lives here — not in ``core`` — because the kernels package must stay
free of ``core`` imports while ``core`` freely imports kernels.
"""

from __future__ import annotations

import jax.numpy as jnp


def nonlinear_terms(u):
    """Elementwise ``(log cosh u, u exp(-u^2/2))`` — the two integrands.

    ``log cosh`` is computed in the overflow-safe form
    ``|u| + log1p(exp(-2|u|)) - log 2``. Both terms are exactly 0 at
    ``u = 0``, which the padded/masked reduction paths (blocked row
    kernel, sharded column moments, chunked streaming sums) rely on:
    zeroed pad entries contribute nothing to the sums.
    """
    au = jnp.abs(u)
    logcosh = au + jnp.log1p(jnp.exp(-2.0 * au)) - jnp.log(2.0)
    uexp = u * jnp.exp(-0.5 * u * u)
    return logcosh, uexp
