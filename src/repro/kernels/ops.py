"""Jit'd public wrappers around the pairwise-statistics kernel.

``pairwise_moments(x_std, c, backend=...)`` dispatches between:

  * ``"ref"``     — pure-jnp oracle (materializes (d, d, m); small shapes).
  * ``"blocked"`` — memory-bounded jnp fallback: lax.scan over row blocks.
                    This is also what the sharded/pjit path lowers, since
                    XLA fuses it well and it needs no pallas on CPU.
  * ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU).

All backends return (M1, M2) of shape (d, d) fp32 with identical values up
to fp32 accumulation tolerance; tests/test_kernels.py sweeps shapes/dtypes
against the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pairwise_stats, ref

_DEFAULT_BACKEND = "blocked"


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def pairwise_moments_blocked(x_std, c, block: int = 64):
    """Row-blocked jnp implementation: O(block * d * m) peak memory.

    Scans over blocks of ``i`` rows; within a block the (block, d, m)
    residual tensor is formed and reduced. XLA fuses the nonlinearities
    into the reduction, so HBM traffic stays ~(d/block) * read(X).
    """
    m, d = x_std.shape
    block = min(block, _round_up(d, 8))  # don't pad tiny d up to a block
    d_pad = _round_up(d, block)
    xt = jnp.pad(x_std.T.astype(jnp.float32), ((0, d_pad - d), (0, 0)))
    c_pad = jnp.pad(c.astype(jnp.float32), ((0, d_pad - d), (0, d_pad - d)))
    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c_pad * c_pad, ref.EPS))

    def body(_, idx):
        xi = jax.lax.dynamic_slice_in_dim(xt, idx * block, block, 0)
        ci = jax.lax.dynamic_slice_in_dim(c_pad, idx * block, block, 0)
        inv = jax.lax.dynamic_slice_in_dim(inv_std, idx * block, block, 0)
        r = xi[:, None, :] - ci[:, :, None] * xt[None, :, :]
        u = r * inv[:, :, None]
        au = jnp.abs(u)
        logcosh = au + jnp.log1p(jnp.exp(-2.0 * au)) - jnp.log(2.0)
        m1 = jnp.mean(logcosh, axis=-1)
        m2 = jnp.mean(u * jnp.exp(-0.5 * u * u), axis=-1)
        return None, (m1, m2)

    _, (m1, m2) = jax.lax.scan(body, None, jnp.arange(d_pad // block))
    m1 = m1.reshape(d_pad, d_pad)[:d, :d]
    m2 = m2.reshape(d_pad, d_pad)[:d, :d]
    return m1, m2


@functools.partial(jax.jit, static_argnames=("backend", "interpret", "block"))
def pairwise_moments(
    x_std,
    c,
    *,
    backend: str = _DEFAULT_BACKEND,
    interpret: bool = True,
    block: int = 64,
):
    """Dispatching wrapper. x_std: (m, d) standardized; c: (d, d).

    Also accepts a leading batch axis — x_std: (b, m, d), c: (b, d, d) —
    and vmaps the selected backend over it, for callers batching at the
    kernel level rather than over whole fits. (The bootstrap/ensemble
    engine in ``repro.core.batched`` vmaps entire fits instead, so its
    traces reach this function with per-element 2-D shapes.)
    """
    if x_std.ndim == 3:
        return jax.vmap(
            lambda xb, cb: pairwise_moments(
                xb, cb, backend=backend, interpret=interpret, block=block
            )
        )(x_std, c)
    m, d = x_std.shape
    if backend == "ref":
        return ref.pairwise_moments_ref(x_std, c)
    if backend == "blocked":
        return pairwise_moments_blocked(x_std, c, block=block)
    if backend == "pallas":
        bi, bj, bm = _pick_blocks(d, m)
        d_pad = _round_up(d, max(bi, bj))
        m_pad = _round_up(m, bm)
        xt = jnp.pad(
            x_std.T.astype(jnp.float32), ((0, d_pad - d), (0, m_pad - m))
        )
        c_pad = jnp.pad(
            c.astype(jnp.float32), ((0, d_pad - d), (0, d_pad - d))
        )
        m1, m2 = pairwise_stats.pairwise_moments_pallas(
            xt, c_pad, m_total=m, bi=bi, bj=bj, bm=bm, interpret=interpret
        )
        return m1[:d, :d], m2[:d, :d]
    raise ValueError(f"unknown backend: {backend}")


def _pick_blocks(d: int, m: int):
    """Heuristic block shapes: MXU/VPU-aligned, VMEM-bounded.

    The (BI, BJ, BM) intermediate is the VMEM working set:
    BI*BM + BJ*BM + 2*BI*BJ*BM fp32 words. Defaults keep it < 4.5 MiB
    (half of a v5e core's 16 MiB VMEM, leaving room for double-buffered
    input streams).
    """
    if d >= 128:
        bi, bj = 8, 128  # lane-aligned j tile
    elif d >= 8:
        bi = bj = 8
    else:
        bi = bj = 8  # tiny d still padded to 8
    if m >= 4096:
        bm = 2048
    elif m >= 512:
        bm = 512
    else:
        bm = 256
    return bi, bj, bm


def standardize(x, eps=ref.EPS):
    """(m, d) -> standardized columns, ddof=0 (matches Algorithm 1)."""
    return ref.standardize(x, axis=0, eps=eps)


def correlation(x_std):
    return ref.correlation(x_std)
