"""Jit'd public wrappers around the pairwise-statistics kernels.

``pairwise_moments(x_std, c, backend=...)`` dispatches between:

  * ``"ref"``     — pure-jnp oracle (materializes (d, d, m); small shapes).
  * ``"blocked"`` — memory-bounded jnp fallback: lax.scan over row blocks.
                    This is also what the sharded/pjit path lowers, since
                    XLA fuses it well and it needs no pallas on CPU.
  * ``"pallas"``  — the Pallas TPU kernel (interpreted automatically when
                    no accelerator backs the process).

All backends return (M1, M2) of shape (d, d) fp32 with identical values up
to fp32 accumulation tolerance; tests/test_kernels.py sweeps shapes/dtypes
against the oracle.

Every block-shape/variant decision in this module goes through the
autotuning dispatcher (:func:`repro.kernels.tune.dispatch`): ``backend``
``None`` lets the registry pick (pallas on accelerators, blocked
elsewhere), ``interpret`` ``None`` resolves to interpret-only-on-CPU,
``tune`` selects the dispatch mode (``"off"`` heuristic / ``"cache"`` /
``"auto"``), and ``plan`` pins an explicit
:class:`~repro.kernels.tune.registry.Plan` (the autotuner measuring a
candidate). Tuned and heuristic plans produce bit-identical moments —
see the parity contract on :mod:`repro.kernels.tune.registry`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import pairwise_stats, ref
from .nonlinearity import nonlinear_terms as _nonlinear_terms  # noqa: F401
from .tune import registry as tune

_DEFAULT_TUNE = "cache"


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def pairwise_moments_blocked(x_std, c, block: int = 64):
    """Row-blocked jnp implementation: O(block * d * m) peak memory.

    Scans over blocks of ``i`` rows; within a block the (block, d, m)
    residual tensor is formed and reduced. XLA fuses the nonlinearities
    into the reduction, so HBM traffic stays ~(d/block) * read(X).
    """
    m, d = x_std.shape
    block = min(block, _round_up(d, 8))  # don't pad tiny d up to a block
    d_pad = _round_up(d, block)
    xt = jnp.pad(x_std.T.astype(jnp.float32), ((0, d_pad - d), (0, 0)))
    c_pad = jnp.pad(c.astype(jnp.float32), ((0, d_pad - d), (0, d_pad - d)))
    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c_pad * c_pad, ref.EPS))

    def body(_, idx):
        xi = jax.lax.dynamic_slice_in_dim(xt, idx * block, block, 0)
        ci = jax.lax.dynamic_slice_in_dim(c_pad, idx * block, block, 0)
        inv = jax.lax.dynamic_slice_in_dim(inv_std, idx * block, block, 0)
        r = xi[:, None, :] - ci[:, :, None] * xt[None, :, :]
        logcosh, uexp = _nonlinear_terms(r * inv[:, :, None])
        m1 = jnp.mean(logcosh, axis=-1)
        m2 = jnp.mean(uexp, axis=-1)
        return None, (m1, m2)

    _, (m1, m2) = jax.lax.scan(body, None, jnp.arange(d_pad // block))
    m1 = m1.reshape(d_pad, d_pad)[:d, :d]
    m2 = m2.reshape(d_pad, d_pad)[:d, :d]
    return m1, m2


@functools.partial(
    jax.jit, static_argnames=("backend", "interpret", "block", "tune_mode",
                              "plan")
)
def pairwise_moments(
    x_std,
    c,
    *,
    backend: str = None,
    interpret: bool = None,
    block: int = None,
    tune_mode: str = _DEFAULT_TUNE,
    plan: tune.Plan = None,
):
    """Dispatching wrapper. x_std: (m, d) standardized; c: (d, d).

    Also accepts a leading batch axis — x_std: (b, m, d), c: (b, d, d) —
    and vmaps the selected backend over it, for callers batching at the
    kernel level rather than over whole fits. (The bootstrap/ensemble
    engine in ``repro.core.batched`` vmaps entire fits instead, so its
    traces reach this function with per-element 2-D shapes.)
    """
    if x_std.ndim == 3:
        return jax.vmap(
            lambda xb, cb: pairwise_moments(
                xb, cb, backend=backend, interpret=interpret, block=block,
                tune_mode=tune_mode, plan=plan,
            )
        )(x_std, c)
    m, d = x_std.shape
    if backend == "ref":
        return ref.pairwise_moments_ref(x_std, c)
    if plan is None:
        plan = tune.dispatch(
            "pairwise_moments", (m, d), str(x_std.dtype), backend,
            mode=tune_mode,
        )
    if plan.backend == "ref":
        return ref.pairwise_moments_ref(x_std, c)
    if plan.backend == "blocked":
        return pairwise_moments_blocked(x_std, c, block=block or plan.block)
    if plan.backend == "pallas":
        interpret = tune.resolve_interpret(interpret)
        bi, bj, bm = plan.bi, plan.bj, plan.bm
        d_pad = _round_up(d, max(bi, bj))
        m_pad = _round_up(m, bm)
        xt = jnp.pad(
            x_std.T.astype(jnp.float32), ((0, d_pad - d), (0, m_pad - m))
        )
        c_pad = jnp.pad(
            c.astype(jnp.float32), ((0, d_pad - d), (0, d_pad - d))
        )
        m1, m2 = pairwise_stats.pairwise_moments_pallas(
            xt, c_pad, m_total=m, bi=bi, bj=bj, bm=bm, interpret=interpret
        )
        return m1[:d, :d], m2[:d, :d]
    raise ValueError(f"unknown backend: {plan.backend}")


def pairwise_moment_sums_rows(
    x_std,
    c,
    row_start,
    tile: int,
    *,
    chunk: int = 512,
    backend: str = None,
    interpret: bool = None,
    tune_mode: str = _DEFAULT_TUNE,
    plan: tune.Plan = None,
):
    """Pairwise residual moment *sums* for the i-row tile
    ``[row_start, row_start + tile)`` against all columns — the
    building block of the mesh execution plan.

    Args:
      x_std: (m_local, d) data standardized by *global* statistics.
             Rows past the valid sample count must be zeroed — both
             moment integrands vanish at 0, so zeroed rows contribute
             nothing to the sums.
      c:     (d, d) global correlation.
      row_start: traced scalar start of the row tile (a device's
             ``axis_index * tile`` under ``shard_map``).
      tile:  static tile height.
    Returns:
      (S1, S2): (tile, d) partial sums over the local sample rows — the
      caller psums over sample shards and divides by the global count.
      ``blocked`` scans over sample chunks (pure jnp); ``pallas`` runs
      the paper's kernel on the local slab (row-tile variant) — the
      kernel composed with ``shard_map`` is the full multi-pod
      configuration. Row-tile block shapes come from the dispatcher
      (``Partition.chunk`` bounds the sample block); non-divisible
      extents are zero-padded here and masked in the kernel.
    """
    m_local, d = x_std.shape
    if plan is None:
        plan = tune.dispatch(
            "pairwise_moment_sums_rows", (tile, d, m_local),
            str(x_std.dtype), backend, mode=tune_mode, chunk=chunk,
        )
    if plan.backend == "pallas":
        interpret = tune.resolve_interpret(interpret)
        bi = plan.bi if plan.bi and tile % plan.bi == 0 else (
            8 if tile % 8 == 0 else 1
        )
        bj = plan.bj if plan.bj and d % plan.bj == 0 else None
        bm = plan.bm if plan.bm else (
            chunk if m_local % chunk == 0 else m_local
        )
        d_pad = d if bj else _round_up(d, 8 if d >= 8 else 1)
        m_pad = _round_up(m_local, bm)
        xt_all = x_std.T  # (d, m_local)
        c_full = c
        if d_pad != d or m_pad != m_local:
            # Pad variables/samples to block multiples: padded columns
            # are sliced back off below, padded samples are masked via
            # m_total (and contribute exact zeros to the sub-sums).
            xt_all = jnp.pad(
                xt_all, ((0, d_pad - d), (0, m_pad - m_local))
            )
            c_full = jnp.pad(c, ((0, d_pad - d), (0, d_pad - d)))
        if bj is None:
            bj = 8 if d_pad % 8 == 0 else 1
        xt_rows = jax.lax.dynamic_slice_in_dim(xt_all, row_start, tile, 0)
        c_rows = jax.lax.dynamic_slice_in_dim(c_full, row_start, tile, 0)
        s1, s2 = pairwise_stats.pairwise_moment_sums_rows(
            xt_rows, xt_all, c_rows, m_total=m_local,
            bi=bi, bj=bj, bm=bm, interpret=interpret,
        )
        return s1[:, :d], s2[:, :d]
    if plan.backend != "blocked":
        raise ValueError(f"unknown backend: {plan.backend}")
    xt = x_std.T  # (d, m_local)
    c_rows = jax.lax.dynamic_slice_in_dim(c, row_start, tile, 0)  # (tile, d)
    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c_rows * c_rows, ref.EPS))

    m_pad = _round_up(m_local, chunk)
    xt = jnp.pad(xt, ((0, 0), (0, m_pad - m_local)))
    n_chunks = m_pad // chunk
    # Mask the padded tail inside the nonlinearities.
    base_valid = jnp.arange(m_pad) < m_local

    def body(carry, k):
        s1, s2 = carry
        xs = jax.lax.dynamic_slice_in_dim(xt, k * chunk, chunk, 1)  # (d, chunk)
        xi = jax.lax.dynamic_slice_in_dim(xs, row_start, tile, 0)   # (tile, chunk)
        valid = jax.lax.dynamic_slice_in_dim(base_valid, k * chunk, chunk, 0)
        r = xi[:, None, :] - c_rows[:, :, None] * xs[None, :, :]
        u = jnp.where(valid[None, None, :], r * inv_std[:, :, None], 0.0)
        logcosh, uexp = _nonlinear_terms(u)
        logcosh = jnp.where(valid[None, None, :], logcosh, 0.0)
        s1 = s1 + jnp.sum(logcosh, axis=-1)
        s2 = s2 + jnp.sum(uexp, axis=-1)
        return (s1, s2), None

    init = (
        jnp.zeros((tile, d), jnp.float32),
        jnp.zeros((tile, d), jnp.float32),
    )
    (s1, s2), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return s1, s2


def pairwise_moment_sums_chunked(
    x_std,
    c,
    *,
    chunk: int = 512,
    backend: str = None,
    interpret: bool = None,
    tune_mode: str = _DEFAULT_TUNE,
    plan: tune.Plan = None,
):
    """Pairwise residual moment *sums* accumulated over sample chunks.

    The streaming entry point: scans ``x_std`` in (chunk, d) sample
    slabs and accumulates the (d, d) moment sums of each slab via
    :func:`pairwise_moment_sums_rows` (the Pallas row-tile kernel for
    the pallas variant, the chunked jnp scan otherwise), so the peak
    residual intermediate is O(chunk * d^2) instead of O(m * d^2) — a
    rolling window's moments cost one chunk of live memory regardless
    of window length. ``chunk`` is the caller's memory bound and fixes
    the outer accumulation grouping; the dispatcher tunes the blocks
    *within* each slab (bit-identical by the parity contract).

    Args:
      x_std: (m, d) data standardized by the *window's* statistics.
      c:     (d, d) window correlation.
    Returns:
      (S1, S2): (d, d) fp32 sums over all m samples; divide by m for the
      means (:func:`pairwise_moments_chunked`). The sample axis is
      zero-padded to a chunk multiple — both integrands vanish at 0, so
      pad rows contribute nothing.
    """
    m, d = x_std.shape
    chunk = max(1, min(chunk, m))
    if plan is None:
        plan = tune.dispatch(
            "pairwise_moment_sums_chunked", (m, d), str(x_std.dtype),
            backend, mode=tune_mode, chunk=chunk,
        )
    inner_plan = dataclasses.replace(plan, op="pairwise_moment_sums_rows")
    if plan.backend != "pallas":
        # The row-tile entry already scans masked (chunk, d) slabs over
        # the full row range for the jnp backend.
        return pairwise_moment_sums_rows(
            x_std, c, 0, d, chunk=chunk, backend=plan.backend,
            interpret=interpret, plan=inner_plan,
        )
    # Pallas path: scan the row-tile kernel over chunk slabs; pad the
    # sample axis with zero rows (both integrands vanish at 0).
    m_pad = _round_up(m, chunk)
    x = jnp.pad(x_std.astype(jnp.float32), ((0, m_pad - m), (0, 0)))
    n_chunks = m_pad // chunk

    def body(carry, k):
        s1, s2 = carry
        xs = jax.lax.dynamic_slice_in_dim(x, k * chunk, chunk, 0)
        t1, t2 = pairwise_moment_sums_rows(
            xs, c, 0, d, chunk=chunk, backend=plan.backend,
            interpret=interpret, plan=inner_plan,
        )
        return (s1 + t1, s2 + t2), None

    init = (
        jnp.zeros((d, d), jnp.float32),
        jnp.zeros((d, d), jnp.float32),
    )
    (s1, s2), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return s1, s2


@functools.partial(
    jax.jit, static_argnames=("chunk", "backend", "interpret", "tune_mode",
                              "plan")
)
def pairwise_moments_chunked(
    x_std,
    c,
    *,
    chunk: int = 512,
    backend: str = None,
    interpret: bool = None,
    tune_mode: str = _DEFAULT_TUNE,
    plan: tune.Plan = None,
):
    """Chunk-accumulated pairwise moment *means*: sums / m.

    Drop-in for :func:`pairwise_moments` with O(chunk)-bounded sample
    intermediates (``FitConfig.moment_chunk`` routes the local plan's
    ordering here). Agrees with the unchunked backends to fp32
    accumulation order.
    """
    m, _ = x_std.shape
    s1, s2 = pairwise_moment_sums_chunked(
        x_std, c, chunk=chunk, backend=backend, interpret=interpret,
        tune_mode=tune_mode, plan=plan,
    )
    inv_m = jnp.float32(1.0 / m)
    return s1 * inv_m, s2 * inv_m


def fused_moment_rows(
    x_raw,
    mu,
    rstd,
    c,
    row_start: int,
    tile: int,
    *,
    interpret: bool = None,
    tune_mode: str = _DEFAULT_TUNE,
    plan: tune.Plan = None,
):
    """Dispatcher-planned wrapper over the fused standardize+moments
    kernel (:func:`repro.kernels.fused_stats.fused_moment_sums`).

    Takes *raw* sample-major data plus the per-variable standardization
    constants, pads every extent to the plan's block multiples (padded
    samples are masked in the kernel; padded variables are sliced back
    off), and returns the (tile, d) moment *sums* for rows
    ``[row_start, row_start + tile)``. ``row_start`` is a host int here
    (the mesh path slices its tile before calling the kernel).
    """
    from .fused_stats import fused_moment_sums

    m, d = x_raw.shape
    if plan is None:
        plan = tune.dispatch(
            "fused_moment_sums", (tile, d, m), str(x_raw.dtype),
            "pallas", mode=tune_mode,
        )
    interpret = tune.resolve_interpret(interpret)
    bi, bj, bm = plan.bi, plan.bj, plan.bm
    tile_pad = _round_up(tile, bi)
    # The row slice must fit inside the padded variable extent even when
    # the tile straddles the end of the real rows.
    d_pad = _round_up(max(d, row_start + tile_pad), bj)
    m_pad = _round_up(m, bm)
    xt = jnp.pad(x_raw.T, ((0, d_pad - d), (0, m_pad - m)))
    mu_pad = jnp.pad(mu.astype(jnp.float32), (0, d_pad - d))
    rstd_pad = jnp.pad(rstd.astype(jnp.float32), (0, d_pad - d))
    c_pad = jnp.pad(
        c.astype(jnp.float32), ((0, d_pad - d), (0, d_pad - d))
    )
    row_slice = slice(row_start, row_start + tile_pad)
    s1, s2 = fused_moment_sums(
        xt[row_slice], xt, mu_pad[row_slice], mu_pad,
        rstd_pad[row_slice], rstd_pad, c_pad[row_slice],
        m_total=m, bi=bi, bj=bj, bm=bm, interpret=interpret,
    )
    return s1[:tile, :d], s2[:tile, :d]


def standardize(x, eps=ref.EPS):
    """(m, d) -> standardized columns, ddof=0 (matches Algorithm 1)."""
    return ref.standardize(x, axis=0, eps=eps)


def correlation(x_std):
    return ref.correlation(x_std)
