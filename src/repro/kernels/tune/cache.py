"""Persistent tuning table: repo-committed defaults + user-local overlay.

The table maps **versioned, shape-bucketed keys** to block plans:

    v1/<device_kind>/<op>/<dtype>/<shape-bucket>

``device_kind`` comes from the first visible device (``"cpu"``,
``"tpu-v5-lite"``, ...), so plans measured on one accelerator never leak
onto another. Shapes are bucketed to the next power of two per axis —
one measured plan covers the whole bucket, which is what lets serving
and streaming sessions hit tuned plans without a first-request search.

Two layers merge at load time:

  * **defaults** — ``default_plans.json`` next to this module, committed
    to the repo. The shipped file carries no entries (every platform
    falls back to the deterministic heuristic until tuned); CI's tune
    job and ``benchmarks/bench_tune.py`` show the round trip.
  * **overlay** — a user-local JSON (``$REPRO_TUNE_CACHE`` or
    ``~/.cache/repro/tune_plans.json``); ``record()`` writes here, and
    overlay entries shadow defaults with the same key.

``TuneTable(offline=True)`` never touches the filesystem and never
returns a tuned entry — ``dispatch`` then degrades to the heuristic
deterministically (the ``FitConfig(tune="off")`` path).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional, Tuple

SCHEMA_VERSION = 1

_DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "default_plans.json")
_OVERLAY_ENV = "REPRO_TUNE_CACHE"

_lock = threading.Lock()
_table: Optional["TuneTable"] = None


def overlay_path() -> str:
    """User-local overlay location (env override > XDG-ish default)."""
    env = os.environ.get(_OVERLAY_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "tune_plans.json"
    )


def bucket_pow2(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floored at ``lo``): one tuned plan per
    bucket keeps the table and the jit cache bounded as shapes drift."""
    b = lo
    while b < n:
        b *= 2
    return b


def shape_bucket(op: str, shape: Tuple[int, ...]) -> str:
    """Canonical bucket token for an op's dispatch shape.

    Shapes are per-op (documented on ``registry.dispatch``):
    2-tuples are (m, d) sample-major; 3-tuples are (tile, d, m).
    """
    if len(shape) == 2:
        m, d = shape
        return f"d{bucket_pow2(d)}.m{bucket_pow2(m, lo=64)}"
    if len(shape) == 3:
        tile, d, m = shape
        return f"t{bucket_pow2(tile)}.d{bucket_pow2(d)}.m{bucket_pow2(m, lo=64)}"
    raise ValueError(f"unsupported dispatch shape for {op!r}: {shape}")


def plan_key(
    device_kind: str, op: str, backend: str, dtype: str, bucket: str
) -> str:
    """Versioned table key. The backend is part of the key so blocked
    and pallas plans tuned at the same bucket never collide."""
    kind = "-".join(str(device_kind).lower().split())
    return f"v{SCHEMA_VERSION}/{kind}/{op}/{backend}/{dtype}/{bucket}"


def _load_json(path: str) -> Dict[str, dict]:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return {}
    if payload.get("version") != SCHEMA_VERSION:
        return {}
    entries = payload.get("entries", {})
    return entries if isinstance(entries, dict) else {}


class TuneTable:
    """Merged defaults + overlay view of the persistent tuning table."""

    def __init__(
        self,
        default_path: Optional[str] = None,
        overlay_path_: Optional[str] = None,
        *,
        offline: bool = False,
    ):
        self.offline = offline
        self.default_path = (
            _DEFAULT_PATH if default_path is None else default_path
        )
        self.overlay_path = (
            overlay_path() if overlay_path_ is None else overlay_path_
        )
        self._defaults: Dict[str, dict] = {}
        self._overlay: Dict[str, dict] = {}
        if not offline:
            self._defaults = _load_json(self.default_path)
            self._overlay = _load_json(self.overlay_path)

    def lookup(self, key: str) -> Optional[dict]:
        """Overlay entry if present, else the committed default."""
        if self.offline:
            return None
        return self._overlay.get(key) or self._defaults.get(key)

    def record(self, key: str, entry: dict, *, persist: bool = True) -> None:
        """Install a measured plan (overlay layer; optionally on disk)."""
        if self.offline:
            raise RuntimeError("cannot record into an offline TuneTable")
        self._overlay[key] = dict(entry)
        if persist:
            self.save_overlay()

    def save_overlay(self) -> None:
        os.makedirs(os.path.dirname(self.overlay_path) or ".", exist_ok=True)
        tmp = self.overlay_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {"version": SCHEMA_VERSION, "entries": self._overlay},
                f,
                indent=1,
                sort_keys=True,
            )
        os.replace(tmp, self.overlay_path)

    def __len__(self) -> int:
        merged = {**self._defaults, **self._overlay}
        return len(merged)


def get_table() -> TuneTable:
    """Process-wide table singleton (loaded once; ``reset_table`` after
    external writes, e.g. in tests)."""
    global _table
    with _lock:
        if _table is None:
            _table = TuneTable()
        return _table


def reset_table() -> None:
    global _table
    with _lock:
        _table = None
