"""Kernel variant registry + the single block-shape decision point.

Every moment-kernel entry point in the repo (the Pallas pair-tile and
row-tile kernels, the fused standardize+moments kernel, the blocked jnp
fallback and the chunked wrappers) is wrapped here as a
:class:`KernelVariant` with declared constraints — sublane/lane
alignment, the VMEM working-set model, sample-axis accumulation
granularity, mesh compatibility. :func:`dispatch` is the **only** place
a ``(bi, bj, bm)`` / row-block decision is made: the wrappers in
``repro.kernels.ops`` (and through them the local, vmap, mesh, and
stream execution plans) all ask it for a :class:`Plan`.

Resolution order inside ``dispatch``:

  1. explicit ``plan`` overrides win (the autotuner measuring a
     candidate, a test pinning a shape);
  2. with ``mode="cache"`` (default) or ``"auto"``, the persistent
     tuning table (:mod:`repro.kernels.tune.cache`) is consulted under
     the versioned ``(device_kind, op, dtype, shape-bucket)`` key; a hit
     is validated against the variant's constraints for the *actual*
     shape before use;
  3. ``mode="auto"`` runs the timed search on a miss (once per bucket,
     persisted to the user overlay);
  4. otherwise — and always for ``mode="off"`` — the deterministic
     heuristic (the old ``ops._pick_blocks`` logic, folded in here).

**Bit-parity contract.** Tuned and heuristic plans for the same op
produce bit-identical moment outputs: block shapes only re-tile the
(i, j) pair space (per-element arithmetic untouched), and the kernels
accumulate the sample axis in fixed :data:`ACCUM_CHUNK`-wide sub-chunks,
so any ``bm`` that is a multiple of ``ACCUM_CHUNK`` yields the same fp32
reduction order (zero-padded tails add exact ``+0.0``). The candidate
generator only emits such ``bm``; ``tests/test_tune.py`` pins the
parity.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace

from . import cache as tune_cache

#: Sample-axis accumulation granularity shared with the Pallas kernels
#: (``pairwise_stats`` / ``fused_stats``): any bm that is a multiple of
#: this produces a bit-identical reduction order (lane width, fp32).
ACCUM_CHUNK = 128

_SUBLANE = 8      # fp32 second-to-last-dim tile
_LANE = 128       # last-dim tile / VPU lane width
_VMEM_BUDGET = int(4.5 * 1024 * 1024)  # bytes; see vmem_bytes()

_MODES = ("off", "cache", "auto")


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def _trace_state_clean() -> bool:
    """True when no jax trace is active. The timed search must not run
    mid-trace: the candidate runs execute eagerly there, but the wall
    times absorb tracing overhead and would persist distorted plans —
    inside a trace, ``mode="auto"`` degrades to the heuristic and the
    search is deferred to an eager dispatch point (engine warm-up, the
    bench harness, a direct ops call)."""
    import jax

    try:
        return jax.core.trace_state_clean()
    except AttributeError:  # pragma: no cover - future jax versions
        return True


def vmem_bytes(bi: int, bj: int, bm: int) -> int:
    """fp32 VMEM working set of one (BI, BJ, BM) grid cell: the two
    streamed input blocks plus the two (BI, BJ, BM) moment
    intermediates (residual/nonlinearity tensors)."""
    return 4 * (bi * bm + bj * bm + 2 * bi * bj * bm)


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """Pallas interpreter only when no accelerator backs the process —
    real hardware must never silently run interpret mode."""
    import jax

    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


@functools.lru_cache(maxsize=1)
def default_backend() -> str:
    """Backend when the caller does not force one: the Pallas kernels on
    an accelerator, the blocked jnp fallback elsewhere."""
    return "pallas" if not default_interpret() else "blocked"


@functools.lru_cache(maxsize=1)
def device_kind() -> str:
    import jax

    return jax.devices()[0].device_kind


@dataclasses.dataclass(frozen=True)
class Constraints:
    """Declared execution constraints of one kernel variant."""

    sublane: int = _SUBLANE        # bi (and bj) alignment quantum
    lane: int = _LANE              # preferred bj / bm alignment
    accum_chunk: int = ACCUM_CHUNK  # bm granularity for bit-stable sums
    vmem_budget: int = _VMEM_BUDGET  # working-set bound for candidates
    mesh_compatible: bool = True   # usable inside shard_map row tiles
    tunable: Tuple[str, ...] = ()  # which Plan fields the search may vary


@dataclasses.dataclass(frozen=True)
class Plan:
    """One block-shape/variant decision. Hashable (jit-static) and
    serializable (tuning table rows are its dict form)."""

    op: str
    variant: str
    backend: str
    bi: int = 0
    bj: int = 0
    bm: int = 0
    block: int = 0      # row block of the blocked jnp backend
    source: str = "heuristic"  # "heuristic" | "tuned" | "override"

    def to_entry(self) -> dict:
        return {
            "variant": self.variant,
            "backend": self.backend,
            "bi": self.bi,
            "bj": self.bj,
            "bm": self.bm,
            "block": self.block,
        }

    @classmethod
    def from_entry(cls, op: str, entry: dict) -> "Plan":
        return cls(
            op=op,
            variant=str(entry.get("variant", "")),
            backend=str(entry.get("backend", "")),
            bi=int(entry.get("bi", 0)),
            bj=int(entry.get("bj", 0)),
            bm=int(entry.get("bm", 0)),
            block=int(entry.get("block", 0)),
            source="tuned",
        )


@dataclasses.dataclass(frozen=True)
class KernelVariant:
    """A registered kernel entry point with its constraints and its
    deterministic fallback plan."""

    name: str
    op: str
    backend: str
    constraints: Constraints
    heuristic: Callable[..., Plan]  # (shape, chunk) -> Plan
    validate: Callable[..., bool]   # (plan, shape, chunk) -> bool


REGISTRY: Dict[Tuple[str, str], KernelVariant] = {}


def register(variant: KernelVariant) -> KernelVariant:
    key = (variant.op, variant.backend)
    if key in REGISTRY:
        raise ValueError(f"duplicate kernel variant for {key}")
    REGISTRY[key] = variant
    return variant


def get_variant(op: str, backend: str) -> KernelVariant:
    try:
        return REGISTRY[(op, backend)]
    except KeyError:
        raise ValueError(
            f"no kernel variant registered for op={op!r} "
            f"backend={backend!r}; known: {sorted(REGISTRY)}"
        ) from None


# ---------------------------------------------------------------------------
# Heuristics (the old static decisions, folded into the fallback path)
# ---------------------------------------------------------------------------


def heuristic_pair_blocks(d: int, m: int) -> Tuple[int, int, int]:
    """MXU/VPU-aligned pair-tile block shapes, VMEM-bounded.

    The (BI, BJ, BM) intermediate is the VMEM working set (see
    :func:`vmem_bytes`); these defaults are the legacy
    ``ops._pick_blocks`` heuristic with its duplicate ``d >= 8`` /
    ``else`` branches collapsed (both returned 8 — tiny d is padded up
    to one sublane tile anyway).
    """
    bi, bj = (8, 128) if d >= 128 else (8, 8)
    if m >= 4096:
        bm = 2048
    elif m >= 512:
        bm = 512
    else:
        bm = 256
    return bi, bj, bm


def _pair_pallas_heuristic(shape, chunk=None) -> Plan:
    m, d = shape
    bi, bj, bm = heuristic_pair_blocks(d, m)
    return Plan(
        op="pairwise_moments", variant="pallas-pair-tile",
        backend="pallas", bi=bi, bj=bj, bm=bm,
    )


def _pair_blocked_heuristic(shape, chunk=None) -> Plan:
    m, d = shape
    block = min(64, _round_up(max(d, 1), _SUBLANE))
    return Plan(
        op="pairwise_moments", variant="blocked-rows",
        backend="blocked", block=block,
    )


def _rows_pallas_heuristic(shape, chunk=None) -> Plan:
    tile, d, m = shape
    bi = _SUBLANE if tile % _SUBLANE == 0 else 1
    bj = _LANE if d % _LANE == 0 else (_SUBLANE if d % _SUBLANE == 0 else 1)
    bm = chunk if chunk and m % chunk == 0 else m
    return Plan(
        op="pairwise_moment_sums_rows", variant="pallas-row-tile",
        backend="pallas", bi=bi, bj=bj, bm=bm,
    )


def _rows_blocked_heuristic(shape, chunk=None) -> Plan:
    # chunk is the caller's memory bound (Partition.chunk / stream
    # chunk); the jnp scan grouping follows it, so it is not tunable —
    # re-grouping would break the chunk-count-invariant sums.
    return Plan(
        op="pairwise_moment_sums_rows", variant="rows-chunked-jnp",
        backend="blocked", bm=int(chunk or 512),
    )


def _chunked_heuristic(backend, name):
    def h(shape, chunk=None) -> Plan:
        m, d = shape
        inner = dispatch_heuristic(
            "pairwise_moment_sums_rows", (d, d, int(chunk or 512)),
            backend=backend, chunk=chunk,
        )
        return dataclasses.replace(
            inner, op="pairwise_moment_sums_chunked", variant=name,
        )
    return h


def _fused_pallas_heuristic(shape, chunk=None) -> Plan:
    tile, d, m = shape
    bi = _SUBLANE
    bj = _LANE if d >= _LANE else _SUBLANE
    bm = 512 if m >= 512 else 256
    return Plan(
        op="fused_moment_sums", variant="pallas-fused",
        backend="pallas", bi=bi, bj=bj, bm=bm,
    )


def _validate_pallas(plan: Plan, shape, chunk=None) -> bool:
    """A tuned Pallas plan is admissible for this shape when its blocks
    are aligned, bit-stable (bm a multiple of the accumulation chunk)
    and within the chunk memory bound when one applies. Divisibility is
    *not* required — the ops wrappers pad to the plan's blocks."""
    if plan.bi < 1 or plan.bj < 1 or plan.bm < 1:
        return False
    if plan.bi % _SUBLANE or plan.bj % _SUBLANE:
        return False
    if plan.bm % ACCUM_CHUNK:
        return False
    if chunk and plan.bm > chunk:
        return False
    return True


def _validate_blocked(plan: Plan, shape, chunk=None) -> bool:
    return plan.block >= 1 and plan.block % _SUBLANE == 0


def _validate_fixed(plan: Plan, shape, chunk=None) -> bool:
    return False  # nothing tunable: heuristic only


register(KernelVariant(
    name="pallas-pair-tile",
    op="pairwise_moments",
    backend="pallas",
    constraints=Constraints(
        mesh_compatible=False, tunable=("bi", "bj", "bm")
    ),
    heuristic=_pair_pallas_heuristic,
    validate=_validate_pallas,
))
register(KernelVariant(
    name="blocked-rows",
    op="pairwise_moments",
    backend="blocked",
    constraints=Constraints(tunable=("block",)),
    heuristic=_pair_blocked_heuristic,
    validate=_validate_blocked,
))
register(KernelVariant(
    name="ref-oracle",
    op="pairwise_moments",
    backend="ref",
    constraints=Constraints(mesh_compatible=False, tunable=()),
    heuristic=lambda shape, chunk=None: Plan(
        op="pairwise_moments", variant="ref-oracle", backend="ref"
    ),
    validate=_validate_fixed,
))
register(KernelVariant(
    name="pallas-row-tile",
    op="pairwise_moment_sums_rows",
    backend="pallas",
    constraints=Constraints(tunable=("bi", "bj", "bm")),
    heuristic=_rows_pallas_heuristic,
    validate=_validate_pallas,
))
register(KernelVariant(
    name="rows-chunked-jnp",
    op="pairwise_moment_sums_rows",
    backend="blocked",
    constraints=Constraints(tunable=()),
    heuristic=_rows_blocked_heuristic,
    validate=_validate_fixed,
))
register(KernelVariant(
    name="chunked-pallas-row-tile",
    op="pairwise_moment_sums_chunked",
    backend="pallas",
    constraints=Constraints(tunable=("bi", "bj")),
    heuristic=_chunked_heuristic("pallas", "chunked-pallas-row-tile"),
    validate=_validate_pallas,
))
register(KernelVariant(
    name="chunked-rows-jnp",
    op="pairwise_moment_sums_chunked",
    backend="blocked",
    constraints=Constraints(tunable=()),
    heuristic=_chunked_heuristic("blocked", "chunked-rows-jnp"),
    validate=_validate_fixed,
))
register(KernelVariant(
    name="pallas-fused",
    op="fused_moment_sums",
    backend="pallas",
    constraints=Constraints(tunable=("bi", "bj", "bm")),
    heuristic=_fused_pallas_heuristic,
    validate=_validate_pallas,
))


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def dispatch_heuristic(
    op: str, shape, *, backend: Optional[str] = None, chunk: Optional[int] = None
) -> Plan:
    """The deterministic fallback plan (no table, no measurement)."""
    backend = backend or default_backend()
    return get_variant(op, backend).heuristic(shape, chunk)


def dispatch(
    op: str,
    shape,
    dtype: str = "float32",
    backend: Optional[str] = None,
    *,
    mode: str = "cache",
    chunk: Optional[int] = None,
    mesh: bool = False,
    table: Optional[tune_cache.TuneTable] = None,
) -> Plan:
    """The single block-shape/variant decision point.

    Args:
      op:     registered op name ("pairwise_moments",
              "pairwise_moment_sums_rows", "pairwise_moment_sums_chunked",
              "fused_moment_sums").
      shape:  static dispatch shape — (m, d) for the pair ops,
              (tile, d, m) for the row/fused ops. Called at trace time,
              where these are Python ints.
      dtype:  input dtype token (part of the tuning key).
      backend: force a backend ("blocked"/"pallas"/"ref"); None lets the
              registry pick (pallas on accelerators, blocked otherwise).
      mode:   "off" (heuristic, deterministic — the offline mode),
              "cache" (tuned table lookup, heuristic fallback; never
              measures), "auto" (search + persist on a miss).
      chunk:  caller's sample-chunk memory bound, when one applies.
      mesh:   require a mesh-compatible (shard_map-safe) variant.
      table:  explicit :class:`TuneTable` (tests/benchmarks); defaults
              to the process singleton.
    """
    with obs_trace.span(
        "kernels.dispatch", op=op, shape=tuple(shape), mode=mode
    ) as sp:
        plan = _dispatch_resolve(
            op, shape, dtype, backend,
            mode=mode, chunk=chunk, mesh=mesh, table=table,
        )
        sp.set(variant=plan.variant, source=plan.source)
    # Per-variant dispatch counts + tuned-vs-heuristic plan provenance
    # (off unless telemetry is enabled; dispatch runs at trace time, so
    # steady-state traffic never reaches this).
    obs_metrics.inc(
        "kernels.dispatch",
        op=op, backend=plan.backend, variant=plan.variant,
        source=plan.source,
    )
    # Profiling on: the decision's analytic cost model + VMEM working
    # set become gauges next to the measured cost records, so a plan
    # whose model disagrees with captured temp_bytes is visible.
    obs_profile.note_plan(
        op, shape, variant=plan.variant, source=plan.source,
        vmem_model_bytes=(
            vmem_bytes(plan.bi, plan.bj, plan.bm)
            if plan.backend == "pallas" and plan.bi else 0
        ),
    )
    return plan


def _dispatch_resolve(
    op: str,
    shape,
    dtype: str = "float32",
    backend: Optional[str] = None,
    *,
    mode: str = "cache",
    chunk: Optional[int] = None,
    mesh: bool = False,
    table: Optional[tune_cache.TuneTable] = None,
) -> Plan:
    if mode not in _MODES:
        raise ValueError(f"unknown tune mode {mode!r}; expected {_MODES}")
    backend = backend or default_backend()
    variant = get_variant(op, backend)
    if mesh and not variant.constraints.mesh_compatible:
        raise ValueError(
            f"variant {variant.name!r} is not mesh-compatible "
            f"(op={op!r}, backend={backend!r})"
        )
    if mode == "off" or not variant.constraints.tunable:
        return variant.heuristic(shape, chunk)

    tbl = table if table is not None else tune_cache.get_table()
    key = tune_cache.plan_key(
        device_kind(), op, backend, dtype, tune_cache.shape_bucket(op, shape)
    )
    entry = tbl.lookup(key)
    if entry is not None:
        plan = Plan.from_entry(op, entry)
        if plan.backend == backend and variant.validate(plan, shape, chunk):
            return plan
        # A recorded plan that fails validation for this shape degrades
        # to the heuristic — deterministically, with no re-search loop.
        return variant.heuristic(shape, chunk)
    if mode == "auto" and not tbl.offline and _trace_state_clean():
        from . import autotune  # lazy: autotune drives the ops wrappers

        tuned = autotune.autotune_op(
            op, shape, dtype=dtype, backend=backend, chunk=chunk, table=tbl
        )
        return tuned.best
    return variant.heuristic(shape, chunk)
