"""Timed block-shape search: candidates -> measurements -> TunePlan.

The candidate generator emits MXU/VPU-aligned ``(BI, BJ, BM)`` grids
bounded by the VMEM working-set model documented on
:func:`repro.kernels.tune.registry.vmem_bytes` (the bound the old
``ops._pick_blocks`` heuristic encoded statically); every sample-axis
block is a multiple of :data:`~repro.kernels.tune.registry.ACCUM_CHUNK`
so all candidates share one fp32 reduction order — tuned plans are
bit-identical to the heuristic, just faster. The search harness times
each candidate on synthetic data per ``(device_kind, op, shape-bucket,
dtype)`` through the *real* ops wrappers (explicit ``plan=`` override,
so dispatch is bypassed, not re-entered) and emits a :class:`TunePlan`;
the winning plan is recorded into the persistent tuning table
(:mod:`repro.kernels.tune.cache`) for ``dispatch(mode="cache")`` to hit
without ever measuring again.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import profile as obs_profile

from . import cache as tune_cache
from . import registry

_BI_GRID = (8, 16)
_BJ_GRID = (8, 16, 128)
_BM_GRID = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass
class Measurement:
    plan: registry.Plan
    seconds: float


@dataclasses.dataclass
class TunePlan:
    """One bucket's measured tuning decision."""

    key: str
    op: str
    dtype: str
    backend: str
    device_kind: str
    shape: Tuple[int, ...]
    best: registry.Plan
    measurements: List[Measurement]

    def to_row(self) -> dict:
        """JSON row for BENCH_kernels.json: the decision plus, when the
        analytic cost model covers this op, each candidate's achieved
        GFLOP/s and roofline fraction against the device-peaks registry
        — and the VMEM working-set model of every Pallas candidate, so
        the tuning table doubles as the model-validation artifact."""
        best_s = min(m.seconds for m in self.measurements)
        cost = obs_profile.analytic_cost(self.op, self.shape)
        peaks = obs_profile.device_peaks(self.device_kind)

        def cand_row(m: "Measurement") -> dict:
            row = {**m.plan.to_entry(), "us": m.seconds * 1e6}
            if m.plan.backend == "pallas" and m.plan.bi:
                row["vmem_model_bytes"] = registry.vmem_bytes(
                    m.plan.bi, m.plan.bj, m.plan.bm
                )
            if cost is not None:
                u = obs_profile.utilization(
                    cost["flops"], cost["bytes"], m.seconds, peaks
                )
                row["gflops_per_s"] = u["gflops_per_s"]
                row["roofline_frac"] = u["roofline_frac"]
            return row

        row = {
            "key": self.key,
            "op": self.op,
            "dtype": self.dtype,
            "backend": self.backend,
            "device_kind": self.device_kind,
            "shape": list(self.shape),
            "best": self.best.to_entry(),
            "best_us": best_s * 1e6,
            "candidates": [cand_row(m) for m in self.measurements],
        }
        if cost is not None:
            u = obs_profile.utilization(
                cost["flops"], cost["bytes"], best_s, peaks
            )
            row["flops"] = cost["flops"]
            row["bytes"] = cost["bytes"]
            row["gflops_per_s"] = u["gflops_per_s"]
            row["roofline_frac"] = u["roofline_frac"]
            row["bound"] = u["bound"]
        return row


def candidate_plans(
    op: str,
    shape,
    *,
    backend: Optional[str] = None,
    chunk: Optional[int] = None,
    quick: bool = False,
) -> List[registry.Plan]:
    """Aligned, VMEM-bounded, bit-stable candidate grid for one op.

    The heuristic plan is always included (dedup'd), so a tuned plan is
    never slower than the fallback the search replaces.
    """
    backend = backend or registry.default_backend()
    variant = registry.get_variant(op, backend)
    cons = variant.constraints
    heur = variant.heuristic(shape, chunk)
    plans: List[registry.Plan] = [heur]
    seen = {(heur.bi, heur.bj, heur.bm, heur.block)}

    def add(**kw):
        p = dataclasses.replace(heur, source="candidate", **kw)
        sig = (p.bi, p.bj, p.bm, p.block)
        if sig in seen:
            return
        seen.add(sig)
        plans.append(p)

    tunable = set(cons.tunable)
    if tunable >= {"bi", "bj", "bm"}:
        m_axis = shape[0] if len(shape) == 2 else shape[2]
        bi_grid = _BI_GRID[:1] if quick else _BI_GRID
        bm_grid = [
            bm for bm in (_BM_GRID[:2] if quick else _BM_GRID)
            if bm % cons.accum_chunk == 0
            and (not chunk or bm <= chunk)
            and bm <= registry._round_up(m_axis, cons.accum_chunk)
        ]
        for bi in bi_grid:
            for bj in _BJ_GRID:
                for bm in bm_grid:
                    if registry.vmem_bytes(bi, bj, bm) > cons.vmem_budget:
                        continue
                    add(bi=bi, bj=bj, bm=bm)
    elif tunable == {"bi", "bj"}:
        for bi in (_BI_GRID[:1] if quick else _BI_GRID):
            for bj in _BJ_GRID:
                if registry.vmem_bytes(bi, bj, heur.bm) > cons.vmem_budget:
                    continue
                add(bi=bi, bj=bj)
    elif tunable == {"block"}:
        d = shape[1]
        cap = registry._round_up(max(d, 1), cons.sublane)
        for block in (8, 32, 64, 128):
            add(block=min(block, cap))
    return plans


def _bench_inputs(op: str, shape, dtype: str, seed: int = 0):
    """Synthetic standardized inputs for one op's timing run."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    if len(shape) == 2:
        m, d = shape
    else:
        _, d, m = shape
    x = rng.laplace(size=(m, d)).astype(np.float32)
    xs = ops.standardize(jnp.asarray(x))
    c = ops.correlation(xs)
    return jnp.asarray(x), xs, c


def _bench_fn(op: str, shape, dtype: str, interpret: Optional[bool], chunk):
    """Build ``run(plan) -> result`` for one op (inputs built once; each
    plan times one *compiled* program — the jitted closure per plan is
    memoized so repeats hit the XLA cache, and the untimed warm-up in
    :func:`measure_plan` absorbs the compile)."""
    import functools as _ft

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    x_raw, xs, c = _bench_inputs(op, shape, dtype)

    if op == "pairwise_moments":
        def make(plan):
            return lambda: ops.pairwise_moments(
                xs, c, backend=plan.backend, interpret=interpret, plan=plan
            )
    elif op == "pairwise_moment_sums_rows":
        tile = shape[0]

        def make(plan):
            f = jax.jit(lambda a, b: ops.pairwise_moment_sums_rows(
                a, b, 0, tile, chunk=chunk or 512,
                backend=plan.backend, interpret=interpret, plan=plan,
            ))
            return lambda: f(xs, c)
    elif op == "pairwise_moment_sums_chunked":
        def make(plan):
            return lambda: ops.pairwise_moments_chunked(
                xs, c, chunk=chunk or 512,
                backend=plan.backend, interpret=interpret, plan=plan,
            )
    elif op == "fused_moment_sums":
        tile = shape[0]
        mu = jnp.mean(x_raw, axis=0)
        rstd = 1.0 / jnp.maximum(jnp.std(x_raw, axis=0), 1e-12)

        def make(plan):
            f = jax.jit(lambda a, b: ops.fused_moment_rows(
                a, mu, rstd, b, 0, tile, interpret=interpret, plan=plan,
            ))
            return lambda: f(x_raw, c)
    else:
        raise ValueError(f"no benchmark runner for op {op!r}")

    make = _ft.lru_cache(maxsize=None)(make)

    def timed(plan):
        return jax.block_until_ready(make(plan)())

    return timed


def measure_plan(run, plan, *, repeats: int = 3) -> float:
    """Min-of-repeats wall time (one untimed warm-up absorbs compile)."""
    run(plan)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        run(plan)
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_op(
    op: str,
    shape,
    *,
    dtype: str = "float32",
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    chunk: Optional[int] = None,
    repeats: int = 3,
    quick: bool = False,
    table: Optional[tune_cache.TuneTable] = None,
    persist: bool = True,
) -> TunePlan:
    """Benchmark the candidate grid for one (op, shape) and record the
    winner in the tuning table under its bucketed key."""
    backend = backend or registry.default_backend()
    interpret = registry.resolve_interpret(interpret)
    cands = candidate_plans(
        op, shape, backend=backend, chunk=chunk, quick=quick
    )
    run = _bench_fn(op, shape, dtype, interpret, chunk)
    measurements = [
        Measurement(plan=p, seconds=measure_plan(run, p, repeats=repeats))
        for p in cands
    ]
    best = min(measurements, key=lambda m: m.seconds).plan
    best = dataclasses.replace(best, source="tuned")
    key = tune_cache.plan_key(
        registry.device_kind(), op, backend, dtype,
        tune_cache.shape_bucket(op, shape),
    )
    tuned = TunePlan(
        key=key,
        op=op,
        dtype=dtype,
        backend=backend,
        device_kind=registry.device_kind(),
        shape=tuple(shape),
        best=best,
        measurements=measurements,
    )
    tbl = table if table is not None else tune_cache.get_table()
    if not tbl.offline:
        entry = best.to_entry()
        entry["time_us"] = min(m.seconds for m in measurements) * 1e6
        tbl.record(key, entry, persist=persist)
    return tuned


def warmup_plans(
    shapes: Sequence[Tuple[int, int]],
    *,
    ops: Sequence[str] = ("pairwise_moments",),
    backend: Optional[str] = None,
    mode: str = "cache",
    chunk: Optional[int] = None,
    table: Optional[tune_cache.TuneTable] = None,
) -> Dict[str, registry.Plan]:
    """Resolve (and, with ``mode="auto"``, measure + persist) the plans
    for the (m, d) dataset shapes a serving/streaming engine expects —
    the warm-up hook ``serve.CausalDiscoveryEngine.warmup`` calls so
    first requests never pay a search."""
    out: Dict[str, registry.Plan] = {}
    for (m, d) in shapes:
        for op in ops:
            shape = (m, d) if op in (
                "pairwise_moments", "pairwise_moment_sums_chunked"
            ) else (d, d, m)
            # Mirror the fit path's clamp (ops.pairwise_moment_sums_chunked
            # bounds chunk by the sample count) so warm-up resolves the
            # same plan the first request will ask for.
            chunk_eff = max(1, min(chunk, m)) if chunk else chunk
            plan = registry.dispatch(
                op, shape, backend=backend, mode=mode, chunk=chunk_eff,
                table=table,
            )
            key = tune_cache.plan_key(
                registry.device_kind(), op, plan.backend, "float32",
                tune_cache.shape_bucket(op, shape),
            )
            out[key] = plan
    return out
