"""Kernel autotuning & dispatch subsystem.

The paper's speed-ups come from hand-tuned kernels; ParaLiNGAM shows the
*scheduling* — which variant runs where, with what block shape —
dominates parallel LiNGAM performance. This package replaces every
static block-shape decision in the repo with one measured, cached,
dispatched subsystem:

  * :mod:`registry <repro.kernels.tune.registry>` — a
    :class:`~repro.kernels.tune.registry.KernelVariant` registry
    wrapping the Pallas pair-tile / row-tile kernels, the fused
    standardize+moments kernel, the blocked jnp fallback and the
    chunked wrappers behind one
    :func:`~repro.kernels.tune.registry.dispatch` interface with
    declared constraints (sublane/lane alignment, the VMEM working-set
    model, sample-axis accumulation granularity, mesh compatibility).
  * :mod:`autotune <repro.kernels.tune.autotune>` — an aligned,
    VMEM-bounded candidate generator plus a timed search harness that
    benchmarks candidates per ``(device_kind, op, shape-bucket,
    dtype)`` and emits a :class:`~repro.kernels.tune.autotune.TunePlan`.
  * :mod:`cache <repro.kernels.tune.cache>` — the persistent JSON
    tuning table (repo-committed ``default_plans.json`` + user-local
    overlay at ``$REPRO_TUNE_CACHE`` or
    ``~/.cache/repro/tune_plans.json``) with shape bucketing and
    versioned keys, so serving and streaming sessions hit tuned plans
    without a first-request search.

Modes (``FitConfig.tune`` / ``dispatch(mode=...)``): ``"off"`` is the
deterministic offline fallback (pure heuristic, no filesystem),
``"cache"`` (default) reads the table and never measures, ``"auto"``
runs the timed search once per bucket and persists the winner. Tuned
and heuristic plans are bit-identical in output — block shapes re-tile
the pair space and the kernels accumulate samples in fixed 128-wide
sub-chunks, so only speed changes (``tests/test_tune.py`` pins this;
``benchmarks/bench_tune.py`` reports heuristic-vs-tuned timings per
bucket into ``BENCH_kernels.json``).
"""

from . import cache, registry  # noqa: F401
from .cache import TuneTable, get_table, plan_key, reset_table, shape_bucket  # noqa: F401
from .registry import (  # noqa: F401
    ACCUM_CHUNK,
    Constraints,
    KernelVariant,
    Plan,
    default_backend,
    default_interpret,
    dispatch,
    dispatch_heuristic,
    get_variant,
    resolve_interpret,
    vmem_bytes,
)

__all__ = [
    "ACCUM_CHUNK",
    "Constraints",
    "KernelVariant",
    "Plan",
    "TuneTable",
    "autotune",
    "cache",
    "default_backend",
    "default_interpret",
    "dispatch",
    "dispatch_heuristic",
    "get_table",
    "get_variant",
    "plan_key",
    "registry",
    "reset_table",
    "resolve_interpret",
    "shape_bucket",
    "vmem_bytes",
]


def __getattr__(name):
    # Lazy: autotune drives the ops wrappers, which import this package
    # (importlib, not ``from . import`` — the latter re-enters this hook).
    if name == "autotune":
        import importlib

        mod = importlib.import_module(".autotune", __name__)
        globals()["autotune"] = mod
        return mod
    raise AttributeError(name)
