"""Pure-jnp oracle for the pairwise-statistics kernel.

Given standardized data ``X_std`` of shape (m, d) and its sample
correlation matrix ``C`` (d, d), computes for every ordered pair (i, j):

    r_ij    = x_i - C[i, j] * x_j                 (regression residual)
    u_ij    = r_ij / std(r_ij) = r_ij / sqrt(1 - C[i, j]^2)
    M1[i,j] = E[log cosh u_ij]
    M2[i,j] = E[u_ij * exp(-u_ij^2 / 2)]

The identity std(r_ij) = sqrt(1 - C_ij^2) holds *exactly* in sample moments
when X is standardized with ddof=0 and C is the ddof=0 sample correlation.

This is the oracle the Pallas kernel is validated against; it materializes
the full (d, d, m) residual tensor, so only use it for small problems.
``pairwise_moments_blocked`` in ops.py is the memory-bounded jnp fallback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .nonlinearity import nonlinear_terms

EPS = 1e-12


def standardize(x, axis=0, eps=EPS):
    """Zero-mean / unit-std (ddof=0) along ``axis``."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    sd = jnp.std(x, axis=axis, keepdims=True)
    return (x - mu) / jnp.maximum(sd, eps)


def correlation(x_std):
    """Sample correlation of standardized data, (m, d) -> (d, d)."""
    m = x_std.shape[0]
    return (x_std.T @ x_std) / m


def pairwise_moments_ref(x_std, c):
    """Oracle: full-materialization pairwise residual moments.

    Args:
      x_std: (m, d) standardized samples.
      c:     (d, d) sample correlation.
    Returns:
      (M1, M2): each (d, d), fp32. Diagonal entries are the moments of the
      degenerate self-residual (r_ii = x_i - x_i = 0 scaled by rsqrt(eps));
      callers mask the diagonal.
    """
    xt = x_std.T.astype(jnp.float32)  # (d, m)
    c = c.astype(jnp.float32)
    r = xt[:, None, :] - c[:, :, None] * xt[None, :, :]  # (d, d, m)
    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c * c, EPS))
    u = r * inv_std[:, :, None]
    logcosh, uexp = nonlinear_terms(u)
    m1 = jnp.mean(logcosh, axis=-1)
    m2 = jnp.mean(uexp, axis=-1)
    return m1, m2
