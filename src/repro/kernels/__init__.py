from .ops import (  # noqa: F401
    correlation,
    pairwise_moments,
    pairwise_moments_blocked,
    standardize,
)
from .pairwise_stats import pairwise_moments_pallas  # noqa: F401
