from . import tune  # noqa: F401
from .nonlinearity import nonlinear_terms  # noqa: F401
from .ops import (  # noqa: F401
    correlation,
    fused_moment_rows,
    pairwise_moments,
    pairwise_moments_blocked,
    pairwise_moments_chunked,
    standardize,
)
from .pairwise_stats import pairwise_moments_pallas  # noqa: F401
