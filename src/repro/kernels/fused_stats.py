"""Fused standardize + pairwise-moments Pallas kernel (§Perf C2+C3).

The baseline kernel (`pairwise_stats.py`) consumes a pre-standardized,
materialized X slab. This variant folds the standardization into the
kernel: it streams the *raw* X tiles (optionally bf16 — C3) and applies
the per-variable affine (mu, rstd) in VMEM before the residual/moment
math, so the ordering step never materializes the standardized slab in
HBM — one full slab write + read saved per ordering iteration, and the
streamed bytes halve again with bf16 input.

Correlation is NOT computed here (it comes from the raw-X MXU matmul with
the affine fold, see core/sharded.py ``fused_standardize=True``); this
kernel only needs C's rows for its i-tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise_stats import _accumulate, _fit_block
from .tune.registry import dispatch

EPS = 1e-12
LOG2 = 0.6931471805599453


def _fused_kernel(x_i_ref, x_j_ref, mu_i_ref, mu_j_ref, rs_i_ref, rs_j_ref,
                  c_ref, m1_ref, m2_ref, *, bm, m_total):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        m1_ref[...] = jnp.zeros_like(m1_ref)
        m2_ref[...] = jnp.zeros_like(m2_ref)

    # Standardize raw tiles in VMEM (affine per variable row).
    xi = x_i_ref[...].astype(jnp.float32)  # (BI, BM) raw
    xj = x_j_ref[...].astype(jnp.float32)  # (BJ, BM) raw
    xi = (xi - mu_i_ref[...][:, None]) * rs_i_ref[...][:, None]
    xj = (xj - mu_j_ref[...][:, None]) * rs_j_ref[...][:, None]
    c = c_ref[...].astype(jnp.float32)     # (BI, BJ)

    sample_ids = k * bm + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bm), 2)
    valid = sample_ids < m_total

    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c * c, EPS))
    r = xi[:, None, :] - c[:, :, None] * xj[None, :, :]
    u = r * inv_std[:, :, None]
    u = jnp.where(valid, u, 0.0)

    au = jnp.abs(u)
    logcosh = au + jnp.log1p(jnp.exp(-2.0 * au)) - LOG2
    logcosh = jnp.where(valid, logcosh, 0.0)
    uexp = u * jnp.exp(-0.5 * u * u)

    # Fixed-width sample sub-sums (see pairwise_stats._accumulate): the
    # reduction order is independent of the tuned bm block.
    _accumulate(m1_ref, m2_ref, logcosh, uexp, bm)


@functools.partial(
    jax.jit,
    static_argnames=("m_total", "bi", "bj", "bm", "interpret"),
)
def fused_moment_sums(
    x_raw_rows,
    x_raw_all,
    mu_rows,
    mu_all,
    rstd_rows,
    rstd_all,
    c_rows,
    *,
    m_total: int,
    bi: int = None,
    bj: int = None,
    bm: int = None,
    interpret: bool = False,
):
    """Moment *sums* for a row tile against all variables, from raw X.

    x_raw_rows: (tile, m_pad) raw (fp32 or bf16 — §Perf C3);
    x_raw_all:  (d_pad, m_pad); mu/rstd: per-variable standardization
    constants; c_rows: (tile, d_pad) correlation rows.
    Returns (S1, S2): (tile, d_pad) fp32 sums over valid samples.
    Block shapes default to the dispatcher's plan, clamped to divisors.
    """
    tile, m_pad = x_raw_rows.shape
    d_pad = x_raw_all.shape[0]
    if bi is None or bj is None or bm is None:
        plan = dispatch(
            "fused_moment_sums", (tile, d_pad, m_pad), backend="pallas"
        )
        bi = bi or _fit_block(tile, plan.bi)
        bj = bj or _fit_block(d_pad, plan.bj)
        bm = bm or (plan.bm if m_pad % plan.bm == 0 else m_pad)
    assert tile % bi == 0 and d_pad % bj == 0 and m_pad % bm == 0
    grid = (tile // bi, d_pad // bj, m_pad // bm)
    kernel = functools.partial(_fused_kernel, bm=bm, m_total=m_total)
    out_shape = [
        jax.ShapeDtypeStruct((tile, d_pad), jnp.float32),
        jax.ShapeDtypeStruct((tile, d_pad), jnp.float32),
    ]
    in_specs = [
        pl.BlockSpec((bi, bm), lambda i, j, k: (i, k)),   # raw rows
        pl.BlockSpec((bj, bm), lambda i, j, k: (j, k)),   # raw all
        pl.BlockSpec((bi,), lambda i, j, k: (i,)),        # mu rows
        pl.BlockSpec((bj,), lambda i, j, k: (j,)),        # mu all
        pl.BlockSpec((bi,), lambda i, j, k: (i,)),        # rstd rows
        pl.BlockSpec((bj,), lambda i, j, k: (j,)),        # rstd all
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),   # corr rows
    ]
    out_specs = [
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x_raw_rows, x_raw_all, mu_rows, mu_all, rstd_rows, rstd_all, c_rows)
