"""Pallas TPU kernel for the LiNGAM pairwise residual-entropy moments.

This is the paper's compute hot-spot (96% of DirectLiNGAM wall-clock):
for every ordered variable pair (i, j) compute the two nonlinear moments
of the standardized regression residual

    u_ij    = (x_i - C_ij * x_j) * rsqrt(1 - C_ij^2)
    M1[i,j] = E_s[log cosh u_ij]
    M2[i,j] = E_s[u_ij * exp(-u_ij^2 / 2)]

TPU adaptation of the paper's CUDA kernel (see DESIGN.md §2):

  * The CUDA version assigns a thread block per ``i`` and threads per ``j``
    with shared-memory tree reductions over samples. On TPU we instead tile
    the (i, j) pair space into (BI, BJ) VMEM blocks and put the *sample*
    axis minor (lane dimension, 128-aligned) so the reduction is a
    vectorized VPU ``sum`` — no synchronization primitives at all.
  * The sample axis is the innermost grid dimension. TPU grid steps execute
    sequentially on a core, so the kernel accumulates partial sums in the
    output VMEM block across sample chunks — the same role the CUDA
    shared-memory accumulator plays, but with a *fixed* reduction order,
    which is why (unlike the paper's abandoned warp-tiling variant) our
    parallel results are deterministic and match the oracle.
  * X is laid out (d, m): contiguous sample vectors per variable. Blocks
    (BI, BM)/(BJ, BM) stream HBM->VMEM via BlockSpec index maps.

Grid: (d/BI, d/BJ, ceil(m/BM)). All block dims are padded by the wrapper
(ops.py) to hardware-friendly multiples; padding samples are masked here.

Block shapes come from the autotuning dispatcher
(:mod:`repro.kernels.tune`): ``bi``/``bj``/``bm`` default to None and are
resolved via ``dispatch`` against the (already padded) input shapes. The
sample axis accumulates in fixed ``ACCUM_CHUNK``-wide sub-chunks, so any
``bm`` that is a multiple of it produces a bit-identical reduction order
— tuned and heuristic plans differ only in speed, never in bits (the
zero-masked padded tail contributes exact ``+0.0`` terms).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tune.registry import ACCUM_CHUNK, dispatch

EPS = 1e-12
LOG2 = 0.6931471805599453


def _fit_block(n: int, preferred: int) -> int:
    """Largest of (preferred, 8, 1) that divides the padded extent — a
    tuned plan from a wider bucket must still tile this array exactly."""
    for b in (preferred, 8, 1):
        if b and n % b == 0:
            return b
    return 1


def _accumulate(m1_ref, m2_ref, logcosh, uexp, bm):
    """Accumulate the (BI, BJ, BM) moment integrands into the output
    block in fixed ACCUM_CHUNK-wide sample sub-sums, so the fp32
    reduction order is independent of the ``bm`` block choice."""
    if bm > ACCUM_CHUNK and bm % ACCUM_CHUNK == 0:
        a1 = m1_ref[...]
        a2 = m2_ref[...]
        for s in range(bm // ACCUM_CHUNK):
            sl = slice(s * ACCUM_CHUNK, (s + 1) * ACCUM_CHUNK)
            a1 = a1 + jnp.sum(logcosh[..., sl], axis=-1)
            a2 = a2 + jnp.sum(uexp[..., sl], axis=-1)
        m1_ref[...] = a1
        m2_ref[...] = a2
    else:
        m1_ref[...] += jnp.sum(logcosh, axis=-1)
        m2_ref[...] += jnp.sum(uexp, axis=-1)


def _kernel(x_i_ref, x_j_ref, c_ref, m1_ref, m2_ref, *, bm, m_total):
    """One (BI, BJ, BM) grid cell: accumulate moment partial sums."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        m1_ref[...] = jnp.zeros_like(m1_ref)
        m2_ref[...] = jnp.zeros_like(m2_ref)

    xi = x_i_ref[...].astype(jnp.float32)  # (BI, BM)
    xj = x_j_ref[...].astype(jnp.float32)  # (BJ, BM)
    c = c_ref[...].astype(jnp.float32)     # (BI, BJ)

    # Mask samples that fall into the zero-padded tail of the last chunk.
    sample_ids = k * bm + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bm), 2)
    valid = sample_ids < m_total  # (1, 1, BM)

    # Residual of regressing x_i on x_j, standardized analytically:
    # std(r) = sqrt(1 - C^2) exactly for ddof=0-standardized columns.
    inv_std = jax.lax.rsqrt(jnp.maximum(1.0 - c * c, EPS))  # (BI, BJ)
    r = xi[:, None, :] - c[:, :, None] * xj[None, :, :]     # (BI, BJ, BM)
    u = r * inv_std[:, :, None]
    u = jnp.where(valid, u, 0.0)

    # log cosh(u) = |u| + log1p(exp(-2|u|)) - log 2  (overflow-safe).
    au = jnp.abs(u)
    logcosh = au + jnp.log1p(jnp.exp(-2.0 * au)) - LOG2
    logcosh = jnp.where(valid, logcosh, 0.0)
    uexp = u * jnp.exp(-0.5 * u * u)  # already 0 where masked

    _accumulate(m1_ref, m2_ref, logcosh, uexp, bm)


def pairwise_moment_sums_rows(
    x_rows,
    x_all,
    c_rows,
    *,
    m_total: int,
    bi: int = None,
    bj: int = None,
    bm: int = None,
    interpret: bool = False,
):
    """Row-tile variant for the sharded (shard_map) path: moment *sums*
    (not means) for rows of ``x_rows`` against all of ``x_all``.

    x_rows: (tile, m_pad); x_all: (d_pad, m_pad); c_rows: (tile, d_pad).
    Returns (S1, S2) of shape (tile, d_pad) — caller psums over sample
    shards and divides by the global sample count. Block shapes default
    to the dispatcher's plan for the (already padded) input shapes.
    """
    tile, m_pad = x_rows.shape
    d_pad = x_all.shape[0]
    if bi is None or bj is None or bm is None:
        plan = dispatch(
            "pairwise_moment_sums_rows", (tile, d_pad, m_pad),
            backend="pallas",
        )
        bi = bi or _fit_block(tile, plan.bi)
        bj = bj or _fit_block(d_pad, plan.bj)
        bm = bm or (plan.bm if m_pad % plan.bm == 0 else m_pad)
    assert tile % bi == 0 and d_pad % bj == 0 and m_pad % bm == 0, (
        tile, d_pad, m_pad, bi, bj, bm)
    grid = (tile // bi, d_pad // bj, m_pad // bm)
    kernel = functools.partial(_kernel, bm=bm, m_total=m_total)
    out_shape = [
        jax.ShapeDtypeStruct((tile, d_pad), jnp.float32),
        jax.ShapeDtypeStruct((tile, d_pad), jnp.float32),
    ]
    in_specs = [
        pl.BlockSpec((bi, bm), lambda i, j, k: (i, k)),
        pl.BlockSpec((bj, bm), lambda i, j, k: (j, k)),
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
    ]
    out_specs = [
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x_rows, x_all, c_rows)


@functools.partial(
    jax.jit, static_argnames=("m_total", "bi", "bj", "bm", "interpret")
)
def pairwise_moments_pallas(
    x_t,
    c,
    *,
    m_total: int,
    bi: int = None,
    bj: int = None,
    bm: int = None,
    interpret: bool = False,
):
    """Pairwise residual moments via the Pallas kernel.

    Args:
      x_t: (d_pad, m_pad) standardized data, variables-major. d_pad must be
           a multiple of max(bi, bj) and m_pad a multiple of bm (the ops.py
           wrapper pads; padded samples are masked via ``m_total``).
      c:   (d_pad, d_pad) sample correlation of the *valid* region.
      m_total: number of valid samples (<= m_pad).
    Returns:
      (M1, M2): (d_pad, d_pad) fp32 moment matrices (means over samples).

    Block shapes default to the dispatcher's plan for the (padded)
    input shapes, clamped to exact divisors.
    """
    d_pad, m_pad = x_t.shape
    if bi is None or bj is None or bm is None:
        plan = dispatch(
            "pairwise_moments", (m_pad, d_pad), backend="pallas"
        )
        bi = bi or _fit_block(d_pad, plan.bi)
        bj = bj or _fit_block(d_pad, plan.bj)
        bm = bm or (plan.bm if m_pad % plan.bm == 0 else m_pad)
    assert d_pad % bi == 0 and d_pad % bj == 0, (d_pad, bi, bj)
    assert m_pad % bm == 0, (m_pad, bm)
    grid = (d_pad // bi, d_pad // bj, m_pad // bm)

    kernel = functools.partial(_kernel, bm=bm, m_total=m_total)
    out_shape = [
        jax.ShapeDtypeStruct((d_pad, d_pad), jnp.float32),
        jax.ShapeDtypeStruct((d_pad, d_pad), jnp.float32),
    ]
    in_specs = [
        pl.BlockSpec((bi, bm), lambda i, j, k: (i, k)),
        pl.BlockSpec((bj, bm), lambda i, j, k: (j, k)),
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
    ]
    out_specs = [
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
    ]
    m1_sum, m2_sum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x_t, x_t, c)
    inv_m = jnp.float32(1.0 / m_total)
    return m1_sum * inv_m, m2_sum * inv_m
