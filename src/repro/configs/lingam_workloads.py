"""The paper's own workload configs (per the brief: one config per
assigned architecture *plus the paper's own*).

Each entry is a (name, m samples, d variables) causal-discovery cell that
runs through the same dry-run / roofline / hillclimb machinery as the LM
architectures via ``repro.core.sharded.make_sharded_causal_order``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class LingamWorkload:
    name: str
    m: int           # samples
    d: int           # variables
    description: str


WORKLOADS: Dict[str, LingamWorkload] = {
    w.name: w
    for w in [
        LingamWorkload(
            "lingam-gene-964", 65_164, 964,
            "Perturb-CITE-seq co-culture dimensions (paper §4.1)",
        ),
        LingamWorkload(
            "lingam-1m-100", 1_000_000, 100,
            "paper Fig. 2 cell: '7 hours on a CPU' at 1M x 100",
        ),
        LingamWorkload(
            "lingam-1m-2048", 1_000_000, 2_048,
            "beyond-paper scale target (hillclimb cell C)",
        ),
        LingamWorkload(
            "varlingam-stocks-487", 4_000, 487,
            "S&P 500 VAR-residual ordering (paper §4.2)",
        ),
    ]
}
