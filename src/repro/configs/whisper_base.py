"""whisper-base [audio] — encoder-decoder, conv frontend (stub).
[arXiv:2212.04356; unverified]
6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865 (padded to 51968).
The audio conv frontend is a stub: input_specs() provides precomputed
frame embeddings (B, 1500, d_model). Decoder uses learned positional
embeddings (whisper has no RoPE); 8 heads < 16-way model axis -> attention
replicated, TP flows through d_ff/vocab.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp="gelu",
    norm="layernorm",
    encoder_layers=6,
    n_frontend_tokens=1500,
    cross_attn_every=1,  # every decoder layer cross-attends (enc-dec)
    rope_theta=0.0,      # 0 -> learned absolute positions
)

SMOKE = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mlp="gelu",
    norm="layernorm",
    encoder_layers=2,
    n_frontend_tokens=16,
    cross_attn_every=1,
    rope_theta=0.0,
)

register(FULL, SMOKE)
