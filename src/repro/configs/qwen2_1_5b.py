"""qwen2-1.5b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
12 heads is not divisible by the 16-way model axis: attention params stay
replicated and TP flows through d_ff / vocab (see dist/sharding.py).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
)

SMOKE = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
)

register(FULL, SMOKE)
