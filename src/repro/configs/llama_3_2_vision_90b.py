"""llama-3.2-vision-90b [vlm] — cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Vision frontend is a stub: input_specs() provides precomputed patch
embeddings (B, n_frontend_tokens, d_model); every 5th layer cross-attends.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_frontend_tokens=2048,
    fsdp=True,
    remat=True,
    optimizer_dtype="float32",
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=10,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    cross_attn_every=5,
    n_frontend_tokens=16,
)

register(FULL, SMOKE)
