"""olmoe-1b-7b [moe] — 64 experts top-8, qk-norm. [arXiv:2409.02060; hf]
16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304, all layers MoE.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    n_experts=64,
    n_experts_active=8,
    d_ff_expert=1024,
    moe_every=1,
)

SMOKE = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    qk_norm=True,
    n_experts=8,
    n_experts_active=2,
    d_ff_expert=64,
    moe_every=1,
)

register(FULL, SMOKE)
