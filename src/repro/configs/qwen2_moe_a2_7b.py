"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=151936.
60 routed experts are padded to 64 inside the MoE layer (router logits of
padded experts pinned to -inf) so the expert dim shards 16-way; the 4
shared experts run as a dense MLP of width 4*1408=5632.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    n_experts_active=4,
    n_shared_experts=4,
    d_ff_expert=1408,
    moe_every=1,
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    qkv_bias=True,
    n_experts=6,
    n_experts_active=2,
    n_shared_experts=2,
    d_ff_expert=64,
    moe_every=1,
)

register(FULL, SMOKE)
