"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP. [arXiv:2402.16819]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Largest assigned arch: FSDP + remat + bf16 optimizer states to fit v5e HBM
(see EXPERIMENTS.md §Dry-run memory table).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp="squared_relu",
    norm="layernorm",
    fsdp=True,
    remat=True,
    optimizer_dtype="bfloat16",
    loss_chunk=512,
)

SMOKE = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mlp="squared_relu",
    norm="layernorm",
)

register(FULL, SMOKE)
