"""Architecture config system: one frozen dataclass per assigned arch.

Every architecture is selectable via ``--arch <id>`` in the launchers; the
registry maps ids to (full config, reduced smoke config, input shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


def round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: Optional[int] = None
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    attn_impl: str = "dense"  # dense | chunked (flash-style, no S^2 in HBM)
    attn_chunk: int = 1024

    # MLP
    mlp: str = "swiglu"  # swiglu | squared_relu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1  # MoE MLP on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2-style SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256

    # hybrid (jamba): 1 attention layer per `attn_every` layers (0 = all attn)
    attn_every: int = 0

    # encoder-decoder / multimodal frontends (stubs provide embeddings)
    encoder_layers: int = 0
    n_frontend_tokens: int = 0  # encoder frames (audio) or image tokens (vlm)
    cross_attn_every: int = 0   # 1 cross-attn layer per k decoder layers

    # numerics / memory knobs
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"
    remat: bool = False
    remat_policy: str = "full"  # full (nothing_saveable) | dots (save matmuls)
    fsdp: bool = False
    loss_chunk: int = 0  # 0 = unchunked cross-entropy; else seq-chunk size

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        """Pad vocab to a lane-aligned multiple of 128 (MXU-friendly; also
        makes every assigned vocab divisible by the 16-way model axis)."""
        return round_up(self.vocab_size, 128)

    @property
    def d_inner(self) -> int:  # mamba
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Families that support the sub-quadratic long_500k decode shape.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")

_REGISTRY: Dict[str, Tuple[ArchConfig, ArchConfig]] = {}


def register(full: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[full.name] = (full, smoke)
    return full


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    _ensure_loaded()
    full, small = _REGISTRY[name]
    return small if smoke else full


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY.keys())


def supported_shapes(cfg: ArchConfig):
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in LONG_CONTEXT_FAMILIES:
        out.append("long_500k")
    return out


def _ensure_loaded():
    # Import the per-arch modules for their registration side-effects.
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        glm4_9b,
        jamba_v0_1_52b,
        llama_3_2_vision_90b,
        mamba2_2_7b,
        nemotron_4_340b,
        olmoe_1b_7b,
        qwen2_1_5b,
        qwen2_moe_a2_7b,
        qwen3_1_7b,
        whisper_base,
    )
