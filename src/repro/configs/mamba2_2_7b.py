"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]
64L d_model=2560 vocab=50280 (padded to 50304), ssm_state=128,
headdim=64 -> d_inner=5120, 80 SSM heads. Runs long_500k (O(1) state).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
)

SMOKE = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=32,
)

register(FULL, SMOKE)
