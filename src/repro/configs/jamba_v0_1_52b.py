"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE every 2 layers.
Layer l is attention iff (l % 8 == 4) — 4 attention layers in 32 (1:7);
SSM layers use the mamba2-style SSD block (DESIGN.md notes this
adaptation; Jamba v0.1 uses mamba1 with d_state=16, we keep d_state=16).
Runs long_500k: only 4 attention layers hold 500k KV.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,
    n_experts=16,
    n_experts_active=2,
    d_ff_expert=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    fsdp=True,
    remat=True,
)

SMOKE = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    attn_every=8,
    n_experts=4,
    n_experts_active=2,
    d_ff_expert=128,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_headdim=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=32,
)

register(FULL, SMOKE)
