"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs_global   / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes_global   / (chips * HBM_BW)
    collective term = collective_bytes_global / (chips * ICI_BW)

``compiled.cost_analysis()`` reports the *per-device* (SPMD-partitioned)
module; we multiply by the mesh size to get global numbers, so the terms
above are per-chip seconds either way. Collective bytes are not in
cost_analysis: we parse the post-partitioning HLO
(``compiled.as_text()``), build a name->bytes table from every
instruction's result shape, and sum the **operand** sizes of each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9       # bytes/s per chip
ICI_BW = 50e9        # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one shaped buffer: f32[128,256]  (layout braces optional)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_OPND_RE = re.compile(r"\(([^)]*)\)")


def _shape_bytes(text: str) -> int:
    """Sum bytes over all shaped buffers appearing in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes (per device) from optimized HLO."""
    sizes: Dict[str, int] = {}
    # First pass: instruction result sizes.
    pending = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type = everything before the opcode; take shapes up to the
        # first opcode occurrence — simplest: shapes in rhs before '('.
        head = rhs.split("(", 1)[0]
        sizes[name.lstrip("%")] = _shape_bytes(head)
        for kind in _COLLECTIVES:
            # match opcode token, e.g. " all-reduce(" or "all-reduce-start("
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                pending.append((kind, rhs))
                break

    out = {k: 0 for k in _COLLECTIVES}
    for kind, rhs in pending:
        opnds = _OPND_RE.search(rhs)
        got = 0
        if opnds:
            for op in opnds.group(1).split(","):
                op = op.strip().lstrip("%")
                # operands may be written as 'f32[..] %name' or just '%name'
                tok = op.split(" ")[-1].lstrip("%")
                if tok in sizes:
                    got += sizes[tok]
                else:
                    got += _shape_bytes(op)
        if got == 0:
            # fallback: result size
            got = _shape_bytes(rhs.split("(", 1)[0])
        out[kind] += got
    return out


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
) -> Dict[str, float]:
    """Per-chip seconds for each roofline term (already per-device)."""
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_n = coll_bytes_per_dev / ICI_BW
    dominant = max(
        [("compute", t_c), ("memory", t_m), ("collective", t_n)],
        key=lambda kv: kv[1],
    )[0]
    total = max(t_c, t_m, t_n)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "dominant": dominant,
        "bound_s": total,
    }


def model_flops(cfg, shape, n_params: int, n_active_params: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode),
    with N = active params for MoE."""
    n = n_active_params
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def count_params(cfg, params_shape) -> Dict[str, float]:
    """Total and active (MoE-discounted) parameter counts from a
    ShapeDtypeStruct tree."""
    import jax

    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.n_experts > 0 and ("/moe/" in pstr or pstr.endswith("router")) \
                and any(k in pstr for k in ("w_gate", "w_up", "w_down")) \
                and "shared" not in pstr:
            active += n * cfg.n_experts_active / max(cfg.n_experts, 1)
        else:
            active += n
    return {"total": float(total), "active": float(active)}
