"""Roofline-term derivation over the device-peaks registry.

Per (kernel x shape x mesh) cell:

    compute term    = FLOPs_per_device       / peak_flops
    memory term     = bytes_per_device       / hbm_bw
    collective term = collective_bytes_per_device / ici_bw

The peaks come from :func:`repro.obs.profile.device_peaks` — detected
from ``jax.devices()[0].device_kind`` (cpu/gpu/tpu entries, first
substring match wins) with ``REPRO_PEAKS`` field overrides — instead of
the hardwired TPU-v5e constants this module used to carry. FLOPs/bytes
come from ``compiled.cost_analysis()`` or the analytic moment-kernel
model (:func:`repro.obs.profile.analytic_cost`); collective bytes from
the optimized-HLO parser (:func:`repro.obs.profile.collective_bytes`,
which lives in the profile layer because cost capture feeds it
automatically).

``python -m repro.analysis.report --roofline`` renders the per-stage
attribution table built on these terms.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.obs.profile import (  # noqa: F401  (re-exported surface)
    DevicePeaks,
    analytic_cost,
    collective_bytes,
    device_peaks,
    utilization,
)


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float = 0.0,
    peaks: Optional[DevicePeaks] = None,
) -> Dict[str, object]:
    """Per-device seconds for each roofline term and the binding one."""
    peaks = peaks or device_peaks()
    t_c = flops_per_dev / peaks.flops_per_s
    t_m = bytes_per_dev / peaks.hbm_bw
    t_n = coll_bytes_per_dev / peaks.ici_bw
    dominant = max(
        [("compute", t_c), ("memory", t_m), ("collective", t_n)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "dominant": dominant,
        "bound_s": max(t_c, t_m, t_n),
        "peaks": peaks.name,
    }
