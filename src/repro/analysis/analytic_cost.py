"""Analytic FLOPs / HBM-bytes model per (arch x shape) cell.

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE (not
x trip count) and reports the post-SPMD per-device module — our layer
stacks are scans, so its FLOPs undercount by ~n_layers. The roofline table
therefore uses this transparent analytic model (every term is a visible
formula below), and EXPERIMENTS.md §Roofline reconciles it against
``cost_analysis`` on a scan-free cell to validate the bookkeeping.

Per-device numbers divide each component by the number of devices that
actually split that component's work under dist/sharding.py rules (e.g.
qwen2-1.5b's 12 attention heads cannot shard on the 16-way model axis, so
attention FLOPs divide only by the batch shards — this asymmetry is real
and visible in the table).

Byte model (bf16 activations/params-in-compute, fp32 optimizer):
  * params: fwd+bwd reads (2 x 2N) + grads fp32 (8N) + AdamW moment/param
    streams (24N, or 16N with bf16 moments) for train; 2N for serve.
  * activations: ~10 x T x D x 2 bytes per layer fwd+bwd (boundary writes
    + reads; XLA fuses the interior), x0.6 when remat (fewer saves, more
    recompute FLOPs instead).
  * attention score materialization: 2 x B x H x S^2 x 2 bytes (fwd; x2
    bwd) — the no-flash-kernel cost that dominates prefill_32k.
  * KV cache: full read per decode step + one-slot write.
  * logits: 3 x T x V x 2 (fwd write, bwd read/write), /loss_chunk-chunked
    cells stream it.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import moe as moe_lib
from repro.models.model import layer_pattern

BF16 = 2
F32 = 4


def _attn_shardable(cfg, n_model=16):
    return cfg.n_heads > 0 and cfg.n_heads % n_model == 0


def _mamba_shardable(cfg, n_model=16):
    return cfg.ssm_heads % n_model == 0


def cell_cost(
    cfg: ArchConfig,
    shape: ShapeConfig,
    *,
    n_model: int = 16,
    n_batch_shards: int = 16,
    moe_impl: str = "scatter",
    flash_attention: bool = False,
    cross_kv_cached: bool = False,
    seq_shard_kv: bool = False,
) -> Dict[str, float]:
    """Global + per-device FLOPs and bytes for one cell."""
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.n_heads, cfg.n_kv_heads
    v = cfg.vocab_padded
    t = b * (s if kind != "decode" else 1)  # tokens processed this step
    s_ctx = s  # context length (cache length for decode)

    train = kind == "train"
    # Units of fwd-equivalent matmul work: 1 fwd + 2 bwd (+ replay when
    # remat: full replays the whole fwd (+1), "dots" saves matmul outputs
    # and replays only elementwise/norm work (~+0.05)). Additive, not
    # multiplicative — remat does NOT re-run the backward.
    if train:
        replay = (
            0.0 if not cfg.remat
            else (1.0 if cfg.remat_policy == "full" else 0.05)
        )
        bwd_mult = 3.0 + replay
    else:
        bwd_mult = 1.0
    remat_mult = 1.0  # kept for variant hooks; folded into bwd_mult above

    pattern = layer_pattern(cfg)
    reps = cfg.n_layers // len(pattern)

    fl: Dict[str, float] = {}
    by: Dict[str, float] = {}
    shards: Dict[str, float] = {}
    nb = n_batch_shards
    nm = n_model
    full = nb * nm

    attn_div = full if _attn_shardable(cfg, nm) else nb
    mamba_div = full if _mamba_shardable(cfg, nm) else nb

    def add(name, flops, bytes_, div):
        fl[name] = fl.get(name, 0.0) + flops
        by[name] = by.get(name, 0.0) + bytes_
        shards[name] = div

    # ---------------- per-layer components
    n_attn = sum(reps for p_ in pattern if p_.mixer == "attn")
    n_cross = sum(reps for p_ in pattern if p_.cross)
    n_mamba = sum(reps for p_ in pattern if p_.mixer == "mamba")
    n_mlp = sum(reps for p_ in pattern if p_.ffn == "mlp")
    n_moe = sum(reps for p_ in pattern if p_.ffn == "moe")

    if n_attn:
        # KV cache shards over kv heads only when divisible; else over the
        # sequence axis if seq_shard_kv (§Perf variant), else batch-only.
        kv_shardable = kv % nm == 0
        cache_div = full if (kv_shardable or seq_shard_kv) else nb
        proj_fl = 2.0 * t * d * (h + 2 * kv) * hd + 2.0 * t * h * hd * d
        if kind == "decode":
            sdp_fl = 2.0 * 2.0 * b * h * s_ctx * hd
            cache_by = 2.0 * b * s_ctx * kv * hd * BF16  # read K+V
            cache_by += 2.0 * b * 1 * kv * hd * BF16     # write one slot
            score_by = 2.0 * b * h * s_ctx * BF16
        else:
            sdp_fl = 2.0 * 2.0 * b * h * s * s * hd  # QK^T + AV (causal ~/2
            # ignored: XLA computes full scores with mask)
            cache_by = 2.0 * b * s * kv * hd * BF16 if kind == "prefill" else 0.0
            score_by = (
                0.0 if flash_attention else 2.0 * b * h * s * s * BF16
            )
        add(
            "attn",
            n_attn * (proj_fl + sdp_fl) * bwd_mult * remat_mult,
            n_attn * score_by * (2.0 if train else 1.0),
            attn_div,
        )
        add("kv_cache", 0.0, n_attn * cache_by, cache_div)

    if n_cross:
        tc = cfg.n_frontend_tokens
        proj_fl = 2.0 * t * d * h * hd + 2.0 * t * h * hd * d
        kvproj = 0.0 if (kind == "decode" and cross_kv_cached) else (
            2.0 * b * tc * d * 2 * kv * hd
        )
        q_rows = t
        sdp_fl = 2.0 * 2.0 * h * q_rows * tc * hd  # QK^T + AV vs frontend
        add(
            "cross_attn",
            n_cross * (proj_fl + kvproj + sdp_fl) * bwd_mult * remat_mult,
            n_cross * (2.0 * q_rows * h * tc * BF16),
            attn_div,
        )

    if n_mlp:
        mats = 3.0 if cfg.mlp == "swiglu" else 2.0
        f = cfg.d_ff
        add(
            "mlp",
            n_mlp * mats * 2.0 * t * d * f * bwd_mult * remat_mult,
            n_mlp * 2.0 * t * f * BF16,
            full,
        )

    if n_moe:
        e_pad = moe_lib.n_experts_padded(cfg)
        k = cfg.n_experts_active
        fe = cfg.d_ff_expert
        mats = 3.0 if cfg.mlp == "swiglu" else 2.0
        tk = t * k * cfg.capacity_factor  # dispatched token-slots
        expert_fl = mats * 2.0 * tk * d * fe
        router_fl = 2.0 * t * d * e_pad
        disp_fl = 0.0
        if moe_impl == "einsum":
            sg = 512 if t % 512 == 0 else t
            cap = max(1, int(sg * k / cfg.n_experts * cfg.capacity_factor))
            disp_fl = 2.0 * 2.0 * t * e_pad * cap * d  # dispatch+combine
        shared_fl = 0.0
        if cfg.n_shared_experts:
            shared_fl = mats * 2.0 * t * d * (cfg.n_shared_experts * fe)
        add(
            "moe",
            n_moe * (expert_fl + router_fl + disp_fl + shared_fl)
            * bwd_mult * remat_mult,
            n_moe * (2.0 * tk * d * BF16 * 2),
            full,
        )

    if n_mamba:
        di = cfg.d_inner
        hm, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        p_in = 2 * di + 2 * cfg.ssm_groups * n + hm
        proj_fl = 2.0 * t * d * p_in + 2.0 * t * di * d
        conv_fl = 2.0 * t * (di + 2 * cfg.ssm_groups * n) * cfg.ssm_conv
        if kind == "decode":
            ssd_fl = 2.0 * 3.0 * b * hm * p * n
            state_by = 2.0 * 2.0 * b * hm * p * n * BF16  # r/w state
        else:
            q = cfg.ssm_chunk
            ssd_fl = 2.0 * t * hm * (q * (n + p) + 2.0 * n * p)
            state_by = 2.0 * t * hm * n * BF16
        add(
            "mamba",
            n_mamba * (proj_fl + conv_fl + ssd_fl) * bwd_mult * remat_mult,
            n_mamba * (2.0 * t * di * BF16 + state_by),
            mamba_div,
        )

    # ---------------- encoder (audio)
    if cfg.encoder_layers and kind != "decode":
        tc = cfg.n_frontend_tokens
        te = b * tc
        enc_fl = cfg.encoder_layers * (
            2.0 * te * d * (h + 2 * kv) * hd
            + 2.0 * te * h * hd * d
            + 4.0 * b * h * tc * tc * hd
            + 2.0 * 2.0 * te * d * cfg.d_ff
        )
        add("encoder", enc_fl * bwd_mult, cfg.encoder_layers * 4.0 * te * d * BF16,
            nb)

    # ---------------- embeddings + head
    add("embed", 0.0, t * d * BF16, full)
    # prefill/decode emit logits only for the last/current position
    t_head = t if train else b
    logits_by = 3.0 * t_head * v * BF16 if train else t_head * v * BF16
    if train and cfg.loss_chunk:
        logits_by = logits_by / max(s // cfg.loss_chunk, 1) + 2.0 * t * d * BF16
    add("head", 2.0 * t_head * d * v * bwd_mult, logits_by, full)

    # ---------------- generic activation traffic
    act_coeff = 10.0 if train else 4.0
    if cfg.remat:
        act_coeff *= 0.6 if cfg.remat_policy == "full" else 0.8
    add("activations", 0.0, cfg.n_layers * act_coeff * t * d * BF16, full)

    # ---------------- parameter + optimizer traffic
    n_params = _param_count(cfg)
    if train:
        opt_by = 24.0 if cfg.optimizer_dtype == "float32" else 16.0
        par_by = (2 * 2 + 8 + opt_by) * n_params
        opt_fl = 20.0 * n_params
    else:
        par_by = 2.0 * n_params
        opt_fl = 0.0
    add("params", opt_fl, par_by, full)

    total_fl = sum(fl.values())
    total_by = sum(by.values())
    dev_fl = sum(fl[k] / shards[k] for k in fl)
    dev_by = sum(by[k] / shards[k] for k in by)
    return {
        "flops_global": total_fl,
        "bytes_global": total_by,
        "flops_per_dev": dev_fl,
        "bytes_per_dev": dev_by,
        "flops_components": fl,
        "bytes_components": by,
        "component_shards": shards,
        "n_params": n_params,
    }


def _param_count(cfg: ArchConfig) -> float:
    """Closed-form parameter count (matches init_params; validated by
    tests/test_analytic_cost.py)."""
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.n_heads, cfg.n_kv_heads
    v = cfg.vocab_padded
    ln = 2 * d if cfg.norm == "layernorm" else d  # scale (+bias)
    total = v * d + ln  # embed + ln_f
    if not cfg.tie_embeddings:
        total += d * v
    pattern = layer_pattern(cfg)
    reps = cfg.n_layers // len(pattern)
    for p_ in pattern:
        n = ln  # ln1
        if p_.mixer == "attn":
            n += d * (h + 2 * kv) * hd + h * hd * d
            if cfg.qkv_bias:
                n += (h + 2 * kv) * hd
            if cfg.qk_norm:
                n += 2 * hd
        else:
            di = cfg.d_inner
            p_in = 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
            n += d * p_in + cfg.ssm_conv * (di + 2 * cfg.ssm_groups * cfg.ssm_state)
            n += (di + 2 * cfg.ssm_groups * cfg.ssm_state)  # conv_b
            n += 3 * cfg.ssm_heads + di + di * d
        if p_.cross:
            n += ln + d * (h + 2 * kv) * hd + h * hd * d
            if cfg.qkv_bias:
                n += (h + 2 * kv) * hd
            if cfg.qk_norm:
                n += 2 * hd
        if p_.ffn == "mlp":
            mats = 3 if cfg.mlp == "swiglu" else 2
            n += ln + mats * d * cfg.d_ff
        elif p_.ffn == "moe":
            from repro.models.moe import n_experts_padded

            e = n_experts_padded(cfg)
            mats = 3 if cfg.mlp == "swiglu" else 2
            n += ln + d * e + e * mats * d * cfg.d_ff_expert
            if cfg.n_shared_experts:
                n += mats * d * (cfg.n_shared_experts * cfg.d_ff_expert)
        total += n * reps
    if cfg.encoder_layers:
        mats = 3 if cfg.mlp == "swiglu" else 2
        per = 2 * ln + d * (h + 2 * kv) * hd + h * hd * d + mats * d * cfg.d_ff
        if cfg.qkv_bias:
            per += (h + 2 * kv) * hd
        total += cfg.encoder_layers * per + ln + cfg.n_frontend_tokens * d
    if cfg.rope_theta == 0.0:
        total += 0  # pos embed counted at runtime size (max_seq); skip
    return float(total)


# ---------------------------------------------------------------------------
# Collective-traffic model (per-device bytes per step).
#
# Conventions: "bytes" = per-device payload of each collective op (operand-
# size convention, matching the HLO parse in roofline.py); ring all-reduce
# wire overhead (2x(n-1)/n) is folded into the ICI_BW constant's headroom.
# Sources of traffic under dist/sharding.py rules:
#   TP   : 2 activation all-reduces per attn/mlp/moe layer fwd (+2x bwd)
#   FSDP : per-pass parameter all-gather (fwd + bwd)
#   DP   : gradient all-reduce (grads sharded over model => /nm)
#   EP   : MoE token all-to-all there-and-back
#   head : logit logsumexp + embed-gather reduce over model axis
# ---------------------------------------------------------------------------
def analytic_collectives(
    cfg: ArchConfig,
    shape: ShapeConfig,
    *,
    n_model: int = 16,
    n_batch_shards: int = 16,
    n_pod: int = 1,
    grad_dtype_bytes: int = 4,
) -> Dict[str, float]:
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    train = kind == "train"
    t_dev = b * (s if kind != "decode" else 1) / n_batch_shards
    d = cfg.d_model
    n_params = _param_count(cfg)

    pattern = layer_pattern(cfg)
    reps = cfg.n_layers // len(pattern)
    n_attn = sum(reps for p_ in pattern if p_.mixer == "attn")
    n_cross = sum(reps for p_ in pattern if p_.cross)
    n_mamba = sum(reps for p_ in pattern if p_.mixer == "mamba")
    n_mlp = sum(reps for p_ in pattern if p_.ffn == "mlp")
    n_moe = sum(reps for p_ in pattern if p_.ffn == "moe")

    out: Dict[str, float] = {}
    bwd = 2.0 if train else 1.0  # fwd=1, +1 bwd mirror

    # TP activation all-reduces (only layers whose weights actually shard).
    tp_layers = 0
    if _attn_shardable(cfg, n_model):
        tp_layers += n_attn + n_cross
    if _mamba_shardable(cfg, n_model):
        tp_layers += n_mamba
    tp_layers += n_mlp + n_moe  # d_ff / experts always shard (padded)
    if n_model > 1:
        out["tp_allreduce"] = tp_layers * 2.0 * t_dev * d * BF16 * bwd
        # vocab-sharded head: logsumexp partials + gathered embed rows
        out["head_allreduce"] = (t_dev * d * BF16 + t_dev * F32) * bwd
    # FSDP parameter all-gathers
    if cfg.fsdp and n_batch_shards > 1:
        out["fsdp_allgather"] = (1.0 + bwd) * 0.5 * 2.0 * n_params * BF16 / n_model
    # DP gradient all-reduce
    if train and n_batch_shards * n_pod > 1:
        out["dp_gradreduce"] = 2.0 * n_params * grad_dtype_bytes / n_model
    # EP all-to-all
    if n_moe and n_model > 1:
        k = cfg.n_experts_active
        out["ep_alltoall"] = n_moe * 2.0 * t_dev * k * d * BF16 * bwd
    return out
