"""Roofline report generator: merges dry-run JSON (compile proof, HLO
collective structure, memory analysis) with the analytic cost model into
the EXPERIMENTS.md §Dry-run and §Roofline tables.

  PYTHONPATH=src python -m repro.analysis.report \
      --dryrun experiments/dryrun_pod.json experiments/dryrun_multipod.json \
      --out experiments/roofline.md
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.analysis import roofline
from repro.analysis.analytic_cost import analytic_collectives, cell_cost
from repro.configs.base import SHAPES, ShapeConfig, get_arch


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def _fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def cell_roofline(arch: str, shape_name: str, mesh_kind: str,
                  *, moe_impl: str = "scatter", **variant) -> Dict:
    """Analytic three-term roofline for one cell."""
    if arch.startswith(("lingam", "varlingam")):
        raise ValueError("use lingam_roofline")
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n_pod = 2 if mesh_kind == "multipod" else 1
    nb = 16 * n_pod
    cost = cell_cost(cfg, shape, n_model=16, n_batch_shards=nb,
                     moe_impl=moe_impl, **variant)
    coll = analytic_collectives(cfg, shape, n_model=16, n_batch_shards=nb,
                                n_pod=n_pod)
    coll_dev = sum(coll.values())
    terms = roofline.roofline_terms(
        cost["flops_per_dev"], cost["bytes_per_dev"], coll_dev
    )
    mf = roofline.model_flops(
        cfg, shape, cost["n_params"], _active_params(cfg, cost["n_params"])
    )
    chips = 256 * n_pod
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "flops_per_dev": cost["flops_per_dev"],
        "bytes_per_dev": cost["bytes_per_dev"],
        "coll_per_dev": coll_dev,
        "coll_parts": coll,
        "terms": terms,
        "model_flops_per_dev": mf / chips,
        "useful_ratio": (mf / chips) / max(cost["flops_per_dev"], 1.0),
        "mfu_bound": (mf / chips) / roofline.PEAK_FLOPS
        / max(terms["bound_s"], 1e-30),
        "n_params": cost["n_params"],
        "flops_components": cost["flops_components"],
        "bytes_components": cost["bytes_components"],
    }


def _active_params(cfg, total: float) -> float:
    if cfg.n_experts == 0:
        return total
    from repro.models.moe import n_experts_padded

    pattern_moe = cfg.n_layers // cfg.moe_every
    mats = 3 if cfg.mlp == "swiglu" else 2
    e = n_experts_padded(cfg)
    expert_params = pattern_moe * e * mats * cfg.d_model * cfg.d_ff_expert
    active_expert = expert_params * cfg.n_experts_active / e
    return total - expert_params + active_expert


def lingam_roofline(name: str, m: int, d: int, mesh_kind: str,
                    chunk: int = 512) -> Dict:
    """Three-term roofline for the sharded causal-ordering scan.

    Per ordering step (d steps total), per device:
      flops: correlation matmul 2*m*d^2 / P  +  pair moments ~30*m*d^2 / P
             (logcosh+uexp ~ 30 flops per (pair, sample))
      bytes: X read twice (standardize + moments) * d/tile reuse:
             blocked rows re-read X per row-tile => (d_tile_loops) reads
      coll:  psum(C) d^2*4 + psum(M tiles) 2*d^2*4/nm + all-gather 2*d^2*4
    """
    n_pod = 2 if mesh_kind == "multipod" else 1
    chips = 256 * n_pod
    nm = 16
    nb = 16 * n_pod
    m_loc = m / nb
    tile = -(-d // nm)
    flops_dev = d * (2.0 * m * d / chips + 30.0 * m_loc * tile * d)
    # bytes: per step, each device streams its X slab once per chunk pass
    # for the moment computation + once for standardize/correlation.
    bytes_dev = d * (3.0 * m_loc * d * 4.0)
    coll_dev = d * (d * d * 4.0 * (1.0 + 2.0 / nm + 2.0))
    terms = roofline.roofline_terms(flops_dev, bytes_dev, coll_dev)
    # useful work per step: correlation 2*m*d^2 + moment math 14*m*d^2,
    # x d ordering steps
    mf = d * (2.0 * m * d * d + 14.0 * m * d * d)
    return {
        "arch": name, "shape": "ordering", "mesh": mesh_kind, "chips": chips,
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "coll_per_dev": coll_dev, "terms": terms,
        "model_flops_per_dev": mf * d / chips / d,  # = mf/chips
        "useful_ratio": (mf / chips) / max(flops_dev, 1.0),
        "n_params": float(d * d),
    }


def make_tables(dryrun_files: List[str]) -> str:
    rows = []
    for f in dryrun_files:
        with open(f) as fh:
            rows.extend(json.load(fh))

    lines = ["## §Dry-run (compile proof + HLO evidence)", ""]
    lines.append(
        "| arch | shape | mesh | chips | compile_s | HLO flops/dev | "
        "HLO coll bytes/dev (parsed) | arg bytes/dev |"
    )
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']} | {r['flops_per_dev']:.3e} | "
            f"{_fmt_b(r['collective_total_per_dev'])} | "
            f"{_fmt_b(r.get('arg_bytes_per_dev', 0))} |"
        )
    lines.append("")
    lines.append(
        "*HLO columns are from `compiled.cost_analysis()` / parsed "
        "partitioned HLO and count while-loop bodies once (XLA semantics); "
        "the §Roofline table uses the trip-count-exact analytic model.*"
    )

    lines += ["", "## §Roofline (analytic, per chip)", ""]
    lines.append(
        "| arch | shape | mesh | compute | memory | collective | dominant | "
        "bound | MODEL_FLOPs/HLO ratio | roofline fraction |"
    )
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    seen = set()
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        if r["arch"].startswith(("lingam", "varlingam")):
            from repro.launch.dryrun import LINGAM_CELLS

            m, d = next((m, d) for n, m, d in LINGAM_CELLS if n == r["arch"])
            a = lingam_roofline(r["arch"], m, d, r["mesh"])
        else:
            a = cell_roofline(r["arch"], r["shape"], r["mesh"])
        t = a["terms"]
        frac = a.get("mfu_bound", a["useful_ratio"])
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
            f"{_fmt_s(t['compute_s'])} | {_fmt_s(t['memory_s'])} | "
            f"{_fmt_s(t['collective_s'])} | **{t['dominant']}** | "
            f"{_fmt_s(t['bound_s'])} | {a['useful_ratio']:.2f} | "
            f"{min(frac, 1.0):.2%} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", nargs="+", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    md = make_tables(args.dryrun)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
    print(md)


if __name__ == "__main__":
    main()
