"""Roofline attribution report: where a fit's seconds and FLOPs go.

Splits a full DirectLiNGAM fit into its ordering / pruning / solve
stages and reports, per stage and per kernel variant: wall seconds,
FLOPs, bytes, achieved GFLOP/s, and fraction of the device roofline
(:mod:`repro.obs.profile` supplies cost capture and the device-peaks
registry). Two modes::

  PYTHONPATH=src python -m repro.analysis.report --roofline
      # live: run a small profiled fit and print the attribution tables

  PYTHONPATH=src python -m repro.analysis.report --roofline --smoke
      # CI: render + validate the committed BENCH_profile.json artifact
      # (no jit work); nonzero exit on a missing/broken artifact

The live path is also the engine of ``benchmarks/bench_profile.py``
(artifact ``BENCH_profile.json``), so the committed rows and this CLI
always agree on schema.
"""

from __future__ import annotations

import argparse
import functools
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional

_REPO_ROOT = Path(__file__).resolve().parents[3]

#: Stage rows must carry these keys — ``--smoke`` validates the
#: committed artifact against them (regress.py tracks best_s/gflops).
STAGE_KEYS = ("stage", "best_s", "flops", "bytes",
              "gflops_per_s", "roofline_frac", "bound")


def _stage_fns():
    """Jitted per-stage programs sharing the full fit's arithmetic."""
    import jax
    import jax.numpy as jnp

    from repro.core import api, pruning

    @functools.partial(jax.jit, static_argnames=("config",))
    def ordering_fn(x, config):
        return api._order_for_config(x.astype(jnp.float32), config)

    @functools.partial(jax.jit, static_argnames=("config",))
    def pruning_fn(x, order, config):
        return pruning.estimate_adjacency(
            x.astype(jnp.float32), order,
            method=config.prune_method, threshold=config.prune_threshold,
            **config.prune_kwargs_dict,
        )

    @jax.jit
    def solve_fn(x, b):
        x = x.astype(jnp.float32)
        xc = x - jnp.mean(x, axis=0, keepdims=True)
        resid = xc - xc @ b.T
        return jnp.mean(resid * resid, axis=0)

    return ordering_fn, pruning_fn, solve_fn


def _record_row(label: str, rec) -> Dict[str, Any]:
    from repro.obs import profile

    row = {"stage": label, **rec.row(profile.device_peaks())}
    row.pop("op", None)
    row.pop("config", None)
    return row


def live_attribution(
    m: int = 512, d: int = 16, *,
    backend: Optional[str] = None, compaction: str = "staged",
    repeats: int = 2, include_pallas: bool = True,
) -> Dict[str, Any]:
    """Run one profiled fit; return {rows, kernels, device}.

    Stages re-execute the fit's three phases as separate jitted
    programs (ordering scan, adjacency solve, residual diagnostics)
    plus the fused ``full_fit`` — so per-stage seconds are directly
    comparable and their sum bounds the fused time from above.
    ``repeats`` timed calls per stage; best-of is reported.
    """
    import dataclasses

    import numpy as np

    from repro.core import api
    from repro.obs import profile

    profile.enable()
    cfg = api.FitConfig(backend=backend, compaction=compaction)
    rng = np.random.default_rng(0)
    # Upper-triangular SEM: x_j = sum_{k<j} w x_k + laplace noise.
    w = np.triu(rng.uniform(0.3, 0.8, (d, d)), 1) * \
        (rng.random((d, d)) < 0.4)
    e = rng.laplace(size=(m, d)).astype(np.float32)
    x = np.linalg.solve(np.eye(d) - w.T, e.T).T.astype(np.float32)

    ordering_fn, pruning_fn, solve_fn = _stage_fns()

    stages: List[Dict[str, Any]] = []
    for _ in range(repeats):
        order = profile.call(ordering_fn, x, cfg,
                             op="report.ordering", shape=x.shape, config=cfg)
        b = profile.call(pruning_fn, x, order, cfg,
                         op="report.pruning", shape=x.shape, config=cfg)
        profile.call(solve_fn, x, b,
                     op="report.solve", shape=x.shape)
        api.fit_fn(x, cfg)  # routes through profile as op="core.fit"
    for label, op, key_cfg in (("ordering", "report.ordering", cfg),
                               ("pruning", "report.pruning", cfg),
                               ("solve", "report.solve", None)):
        rec = profile.get(op, x.shape, key_cfg)
        if rec is not None:
            stages.append(_record_row(label, rec))
    full = profile.get("core.fit", x.shape, cfg)
    if full is not None:
        stages.append(_record_row("full_fit", full))

    kernels = kernel_variant_rows(
        m, d, repeats=repeats, include_pallas=include_pallas
    )
    return {
        "m": m, "d": d,
        "rows": stages,
        "kernels": kernels,
        "device": dataclasses.asdict(profile.device_peaks()),
    }


def kernel_variant_rows(
    m: int, d: int, *, repeats: int = 2, include_pallas: bool = True,
) -> List[Dict[str, Any]]:
    """Per-kernel-variant utilization at one (m, d): each registered
    ``pairwise_moments`` backend timed through the profiled path (the
    Pallas variant runs interpreted on cpu — slow but measured)."""
    import numpy as np

    from repro.kernels import ops
    from repro.kernels.tune import registry
    from repro.obs import profile

    profile.enable()
    rng = np.random.default_rng(1)
    x = rng.normal(size=(m, d)).astype(np.float32)
    x_std = (x - x.mean(0)) / x.std(0)
    c = (x_std.T @ x_std) / m

    backends = ["blocked"] + (["pallas"] if include_pallas else [])
    rows: List[Dict[str, Any]] = []
    for backend in backends:
        variant = registry.get_variant("pairwise_moments", backend).name
        op = f"report.kernel.{backend}"
        for _ in range(repeats):
            profile.call(
                ops.pairwise_moments, x_std, c,
                op=op, shape=(m, d), backend=backend,
            )
        rec = profile.get(op, (m, d))
        if rec is None:
            continue
        row = _record_row(variant, rec)
        row["variant"] = row.pop("stage")
        row["backend"] = backend
        # The analytic model next to the measured numbers: how far the
        # documented flop/byte budget sits from XLA's own count.
        model = profile.analytic_cost("pairwise_moments", (m, d))
        if model is not None:
            row["model_flops"] = model["flops"]
            row["model_intensity"] = model["intensity"]
        rows.append(row)
    return rows


def _fmt_table(rows: List[Dict[str, Any]], label_key: str) -> str:
    head = (f"{'stage':<22} {'seconds':>10} {'GFLOP':>10} {'GB':>10} "
            f"{'GFLOP/s':>10} {'%roof':>7} {'bound':>8}")
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(
            f"{str(r.get(label_key, '?')):<22} "
            f"{r.get('best_s', 0.0):>10.4g} "
            f"{r.get('flops', 0.0) / 1e9:>10.4g} "
            f"{r.get('bytes', 0.0) / 1e9:>10.4g} "
            f"{r.get('gflops_per_s', 0.0):>10.3g} "
            f"{100.0 * r.get('roofline_frac', 0.0):>6.2f}% "
            f"{str(r.get('bound', '-')):>8}"
        )
    return "\n".join(lines)


def render(payload: Dict[str, Any]) -> str:
    dev = payload.get("device", {})
    out = [
        f"roofline attribution — m={payload.get('m')} d={payload.get('d')} "
        f"device={dev.get('name', '?')} "
        f"(peak {dev.get('flops_per_s', 0) / 1e9:.0f} GFLOP/s, "
        f"{dev.get('hbm_bw', 0) / 1e9:.0f} GB/s)",
        "",
        "per-stage attribution:",
        _fmt_table(payload.get("rows", []), "stage"),
        "",
        "per-kernel-variant utilization (pairwise_moments):",
        _fmt_table(payload.get("kernels", []), "variant"),
    ]
    return "\n".join(out)


def smoke(repo_root: Path = _REPO_ROOT) -> int:
    """Validate + render the committed BENCH_profile.json (CI mode)."""
    p = repo_root / "BENCH_profile.json"
    if not p.exists():
        print(f"error: {p} missing", file=sys.stderr)
        return 1
    try:
        payload = json.loads(p.read_text())
    except ValueError as e:
        print(f"error: {p} unparsable: {e}", file=sys.stderr)
        return 1
    rows = payload.get("rows", [])
    kernels = payload.get("kernels", [])
    broken = 0
    for row in rows:
        missing = [k for k in STAGE_KEYS if k not in row]
        if missing:
            print(f"error: stage row {row.get('stage', '?')!r} missing "
                  f"{missing}", file=sys.stderr)
            broken += 1
    if not rows:
        print("error: BENCH_profile.json has no stage rows", file=sys.stderr)
        broken += 1
    if not kernels:
        print("error: BENCH_profile.json has no kernel rows",
              file=sys.stderr)
        broken += 1
    print(render(payload))
    print(f"\nsmoke: {len(rows)} stage rows, {len(kernels)} kernel rows, "
          f"{'OK' if not broken else 'BROKEN'}")
    return 1 if broken else 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Per-stage / per-kernel roofline attribution report.")
    ap.add_argument("--roofline", action="store_true",
                    help="produce the attribution report (the only mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="validate + render committed BENCH_profile.json "
                         "instead of running a live fit")
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--backend", type=str, default=None,
                    help="force the fit backend (default: registry pick)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the (interpreted-on-cpu) Pallas variant row")
    ap.add_argument("--out", type=str, default=None,
                    help="also write the payload as JSON")
    args = ap.parse_args(argv)

    if not args.roofline:
        ap.error("nothing to do: pass --roofline")
    if args.smoke:
        return smoke()

    payload = live_attribution(
        args.m, args.d, backend=args.backend,
        include_pallas=not args.no_pallas,
    )
    print(render(payload))
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=1))
        print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
