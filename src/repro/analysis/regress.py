"""BENCH regression tracker: diff perf artifacts against a baseline.

The benchmark harness (``benchmarks/run.py``) mirrors every run to
repo-root ``BENCH_<stem>.json`` artifacts — the committed perf
trajectory. This module closes the loop: it loads those baselines,
obtains a *current* set (a fresh quick run, or a directory of
pre-produced artifacts), extracts every timing/throughput metric from
both, and reports per-kernel / per-stage deltas with tolerance bands.
Any metric slower than ``--tol`` (with an absolute floor ``--min-abs``
on time metrics, so microsecond noise cannot fail a build) makes the
process exit nonzero — the CI contract.

Metric extraction is schema-driven, not artifact-specific: any numeric
leaf whose key ends in ``_s`` / ``_ms`` / ``_us`` (or is ``us``) is a
lower-is-better time; any key ending ``_per_s`` or starting
``speedup`` is a higher-is-better rate. Rows are labeled by their
identifying fields (op/bucket/kind/mesh/cell + shape), so the same row
matches across runs even if list order changes.

Usage::

  python -m repro.analysis.regress --smoke        # validate committed
                                                  # artifacts, exit 0
  python -m repro.analysis.regress                # fresh quick run vs
                                                  # committed baselines
  python -m repro.analysis.regress --current-dir DIR --tol 0.3
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

_REPO_ROOT = Path(__file__).resolve().parents[3]

# Artifact stems this tracker knows how to regenerate (stem -> bench
# name in benchmarks.run.BENCHES).
STEM_TO_BENCH = {
    "bootstrap": "bootstrap",
    "sharded": "sharded",
    "stream": "stream",
    "kernels": "tune",
    "infer": "infer",
    "drift": "drift",
    "profile": "profile",
}

# Row fields that identify a row across runs (never treated as metrics).
_ID_KEYS = ("op", "bucket", "cell", "kind", "mesh", "name", "backend",
            "variant", "m", "d", "n_queries", "n_sampling", "shape", "stage")
_SKIP_KEYS = {"bench", "quick", "timestamp", "provenance", "device_kind",
              "n_candidates", "bi", "bj", "bm", "block",
              # cost-accounting fields: descriptive, not pass/fail perf
              # (utilization moves with the peaks registry, not the code)
              "flops", "bytes", "arg_bytes", "out_bytes", "temp_bytes",
              "vmem_model_bytes", "intensity", "roofline_frac", "device"}


def _direction(key: str) -> Optional[Tuple[str, float]]:
    """(direction, to_seconds_scale) for a metric key, None if not a
    tracked metric. Direction: "lower" (time) or "higher" (rate)."""
    if key.endswith("_per_s") or key.startswith("speedup"):
        return ("higher", 1.0)
    if key.endswith("_s"):
        return ("lower", 1.0)
    if key.endswith("_ms"):
        return ("lower", 1e-3)
    if key == "us" or key.endswith("_us") or "_us_" in key:
        return ("lower", 1e-6)
    return None


def _row_label(row: dict, idx: int) -> str:
    parts = []
    for k in _ID_KEYS:
        if k in row and not isinstance(row[k], dict):
            v = row[k]
            v = "x".join(str(s) for s in v) if isinstance(v, (list, tuple)) \
                else v
            parts.append(f"{k}={v}")
    return "[" + (",".join(parts) if parts else f"row{idx}") + "]"


def collect_metrics(payload, prefix: str = "") -> Dict[str, Tuple[str, float]]:
    """Flatten an artifact payload into {metric_path: (direction,
    value_in_canonical_units)} — times normalized to seconds."""
    out: Dict[str, Tuple[str, float]] = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                if k in _SKIP_KEYS or k in _ID_KEYS:
                    continue
                if isinstance(v, bool):
                    continue
                if isinstance(v, (int, float)):
                    d = _direction(k)
                    if d is not None and math.isfinite(v):
                        out[f"{path}{k}"] = (d[0], float(v) * d[1])
                elif isinstance(v, (dict, list)):
                    walk(v, f"{path}{k}.")
        elif isinstance(node, list):
            for i, row in enumerate(node):
                if isinstance(row, dict):
                    walk(row, f"{path[:-1]}{_row_label(row, i)}.")

    walk(payload, prefix)
    return out


@dataclasses.dataclass
class Delta:
    """One metric compared across baseline and current runs."""

    metric: str
    direction: str          # "lower" | "higher"
    base: Optional[float]
    cur: Optional[float]
    status: str = "ok"      # ok | improved | REGRESSED | new | missing
    ratio: Optional[float] = None   # cur/base


def compare(base: Dict[str, Tuple[str, float]],
            cur: Dict[str, Tuple[str, float]],
            *, tol: float, min_abs: float) -> List[Delta]:
    """Per-metric deltas. A lower-is-better metric regresses when it is
    both ``tol`` relatively slower *and* ``min_abs`` seconds absolutely
    slower; a rate regresses on the relative band alone."""
    deltas: List[Delta] = []
    for metric in sorted(set(base) | set(cur)):
        bd, cd = base.get(metric), cur.get(metric)
        if bd is None:
            deltas.append(Delta(metric, cd[0], None, cd[1], status="new"))
            continue
        if cd is None:
            deltas.append(Delta(metric, bd[0], bd[1], None, status="missing"))
            continue
        direction, b = bd
        _, c = cd
        ratio = c / b if b else float("inf")
        d = Delta(metric, direction, b, c, ratio=ratio)
        if direction == "lower":
            if c > b * (1.0 + tol) and (c - b) > min_abs:
                d.status = "REGRESSED"
            elif c < b * (1.0 - tol):
                d.status = "improved"
        else:
            if c < b * (1.0 - tol):
                d.status = "REGRESSED"
            elif c > b * (1.0 + tol):
                d.status = "improved"
        deltas.append(d)
    return deltas


def load_artifacts(root: Path, stems) -> Dict[str, dict]:
    """{stem: payload} for every BENCH_<stem>.json present in root."""
    out = {}
    for stem in stems:
        p = root / f"BENCH_{stem}.json"
        if p.exists():
            out[stem] = json.loads(p.read_text())
    return out


def run_fresh(stems) -> Dict[str, dict]:
    """Regenerate artifacts by running the quick benches in-process
    (payloads stay in memory — committed baselines are not touched)."""
    if str(_REPO_ROOT) not in sys.path:
        sys.path.insert(0, str(_REPO_ROOT))
    from benchmarks.run import BENCHES  # noqa: PLC0415

    out = {}
    for stem in stems:
        bench = STEM_TO_BENCH[stem]
        print(f"--- regenerating {stem} (bench:{bench}, quick) ---",
              flush=True)
        res = BENCHES[bench](quick=True)
        out[stem] = res if isinstance(res, dict) else {"rows": res}
    return out


def _fmt(v: Optional[float]) -> str:
    return "-" if v is None else f"{v:.6g}"


def report(all_deltas: Dict[str, List[Delta]], *, verbose: bool) -> int:
    """Print the per-artifact delta tables; returns the number of
    regressed metrics."""
    n_reg = 0
    for stem, deltas in all_deltas.items():
        flagged = [d for d in deltas
                   if d.status in ("REGRESSED", "improved", "missing")]
        n_reg += sum(d.status == "REGRESSED" for d in deltas)
        print(f"\n== {stem}: {len(deltas)} metrics, "
              f"{sum(d.status == 'REGRESSED' for d in deltas)} regressed, "
              f"{sum(d.status == 'improved' for d in deltas)} improved ==")
        for d in (deltas if verbose else flagged):
            arrow = "v" if d.direction == "lower" else "^"
            print(f"  {d.status:<9} {arrow} {d.metric}: "
                  f"base={_fmt(d.base)} cur={_fmt(d.cur)}"
                  + (f" ratio={d.ratio:.3f}" if d.ratio else ""))
    return n_reg


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff BENCH_*.json perf artifacts against a baseline; "
                    "exit nonzero on > tolerance slowdowns.")
    ap.add_argument("--baseline-dir", type=Path, default=_REPO_ROOT,
                    help="directory of baseline BENCH_*.json "
                         "(default: repo root — the committed trajectory)")
    ap.add_argument("--current-dir", type=Path, default=None,
                    help="directory of already-produced current artifacts; "
                         "omitted, the quick benches run fresh in-process")
    ap.add_argument("--only", action="append", default=None,
                    help="artifact stem(s) to check "
                         f"(default: all of {sorted(STEM_TO_BENCH)})")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="relative tolerance band (default 0.25 — quick "
                         "benches on shared CI runners are noisy)")
    ap.add_argument("--min-abs", type=float, default=0.005,
                    help="absolute floor (seconds) a time metric must "
                         "slow down by to regress (default 5ms)")
    ap.add_argument("--smoke", action="store_true",
                    help="validate committed artifacts and self-compare "
                         "(no fresh run); nonzero only on broken artifacts")
    ap.add_argument("--json", type=Path, default=None,
                    help="also dump the delta report as JSON")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every metric, not only flagged ones")
    args = ap.parse_args(argv)

    stems = args.only or sorted(STEM_TO_BENCH)
    unknown = [s for s in stems if s not in STEM_TO_BENCH]
    if unknown:
        ap.error(f"unknown artifact stem(s) {unknown}; "
                 f"known: {sorted(STEM_TO_BENCH)}")

    baselines = load_artifacts(args.baseline_dir, stems)
    if not baselines:
        print(f"error: no BENCH_*.json baselines in {args.baseline_dir}",
              file=sys.stderr)
        return 2

    if args.smoke:
        current = baselines
    elif args.current_dir is not None:
        current = load_artifacts(args.current_dir, stems)
    else:
        current = run_fresh(list(baselines))

    all_deltas: Dict[str, List[Delta]] = {}
    broken = 0
    for stem, base_payload in baselines.items():
        base = collect_metrics(base_payload)
        if not base:
            print(f"error: BENCH_{stem}.json has no recognizable metrics",
                  file=sys.stderr)
            broken += 1
            continue
        if stem not in current:
            print(f"warning: no current artifact for {stem}; skipping",
                  file=sys.stderr)
            continue
        all_deltas[stem] = compare(
            base, collect_metrics(current[stem]),
            tol=args.tol, min_abs=args.min_abs,
        )

    n_reg = report(all_deltas, verbose=args.verbose or args.smoke)
    if args.json:
        args.json.write_text(json.dumps(
            {stem: [dataclasses.asdict(d) for d in ds]
             for stem, ds in all_deltas.items()}, indent=1))
        print(f"\nwrote {args.json}")

    if args.smoke:
        ok = not broken
        print(f"\nsmoke: {len(all_deltas)} artifacts, "
              f"{sum(len(d) for d in all_deltas.values())} metrics, "
              f"{'OK' if ok else 'BROKEN'}")
        return 0 if ok else 1
    if n_reg:
        print(f"\nFAIL: {n_reg} metric(s) regressed beyond "
              f"tol={args.tol} / min-abs={args.min_abs}s")
        return 1
    print("\nOK: no regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
