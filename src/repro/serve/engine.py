"""Batched causal-discovery serving engine.

``CausalDiscoveryEngine`` serves DirectLiNGAM traffic:
fit requests are grouped by (m, d) shape, padded to a fixed micro-batch,
and executed through the functional core's batched engine
(``repro.core.batched.fit_many``) — one compile per dataset shape, then
every full micro-batch is a single device-parallel program.

The engine also admits *streaming* sessions (``open_stream`` /
``post_chunk`` / ``flush_streams``): each session owns a rolling-window
VarLiNGAM over the incremental moment store (:mod:`repro.stream`);
posted chunks advance the window in O(chunk d^2), and due refits across
sessions are bucketed by (residual shape, fit config) and executed
through ``batched.fit_many_from_stats`` — a burst of due windows costs
one device-parallel program, and each client gets back a
:class:`~repro.stream.session.GraphDelta` rather than the full matrix.
Monitored sessions (:mod:`repro.stream.monitor`) additionally score
every chunk against the served graph; drift alerts make a session due
immediately, ride out on its next delta, and are collectable through
:meth:`CausalDiscoveryEngine.poll_alerts`.

Fitted (or streaming) graphs are *queryable*: ``query`` admits a mixed
micro-batch of effect / intervention / root-cause requests
(:mod:`repro.infer.query`) and executes each (kind, shape) bucket as
one compiled device-parallel program; stream-session ids resolve to
the session's live estimate with moments from its incremental store.

The engine is instrumented with :mod:`repro.obs` (off by default):
spans around run/flush/query, histograms for queue wait, bucket fill,
and flush latency, and a deferral counter for the bounded-deferral
auto-flush rule. Per-session refit failures during a flush never abort
the batch — they surface as :class:`FlushError` records in
``last_flush_errors`` (telemetry on or off) and the failed sessions
stay due for retry.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import api as lingam_api
from repro.core import batched as lingam_batched
from repro.infer import query as query_lib
from repro.obs import metrics as obs_metrics
from repro.stream import session as stream_session
from repro.stream import window as stream_window


@dataclasses.dataclass
class FitRequest:
    """One causal-discovery request: a dataset to fit."""

    data: np.ndarray  # (m, d) float32
    result: Optional[lingam_api.FitResult] = None  # numpy-leaved on return


@dataclasses.dataclass
class FlushError:
    """One session's failed refit during :meth:`CausalDiscoveryEngine.
    flush_streams`, surfaced as data instead of aborting the flush.

    ``stage`` names where the failure happened: ``"prepare"`` (the
    session's refit plan could not be built), ``"fit"`` (the batched —
    or fallback per-session — fit program raised), or ``"finish"``
    (residual-variance finish / delta application). A failed session
    keeps its due state, so the next post or explicit flush retries it.
    """

    sid: str            # "*" for a whole-bucket program failure
    stage: str          # "prepare" | "fit" | "finish"
    bucket: Optional[Tuple[Tuple[int, ...], lingam_api.FitConfig]]
    error: Exception

    def summary(self) -> str:
        shape = None if self.bucket is None else self.bucket[0]
        return (
            f"flush error [{self.stage}] session={self.sid} "
            f"bucket={shape}: {type(self.error).__name__}: {self.error}"
        )


class CausalDiscoveryEngine:
    """Micro-batched DirectLiNGAM serving over the functional core.

    Requests with the same (m, d) shape share compiled programs. Two
    regimes, selected by the config's execution plan:

    * **vmap plan** (``config.partition is None``, the default): partial
      batches are padded (by repeating the first dataset) up to the next
      power-of-two bucket <= ``batch_size``, so a singleton request
      costs one fit — not ``batch_size`` fits — while the compile cache
      stays bounded at log2(batch_size) entries per dataset shape.
    * **mesh plan** (``config.partition`` set): each dataset is one
      ``shard_map`` program over the whole device mesh (all devices
      cooperate on a single fit — the d >> one-device regime), so
      requests run sequentially; the per-(m, d) shape bucket still
      reuses the sharded compile cache, which is what keeps mixed
      traffic from recompiling per request.

    Streaming traffic is the third regime: ``open_stream`` admits a
    session, ``post_chunk`` advances its rolling window (cheap — no
    fit), and due refits are *batched across sessions* on flush through
    ``fit_many_from_stats`` with the same shape-bucketed padding
    discipline as the one-shot path. ``post_chunk`` auto-flushes once a
    full micro-batch of sessions is due.

    ``warmup(shapes)`` pre-resolves the kernel block plans (running the
    autotuner's timed search when the config says ``tune="auto"``) and
    pre-compiles the fit programs for the expected dataset shapes, so
    first requests pay neither a plan search nor a compile.
    """

    def __init__(self, config: Optional[lingam_api.FitConfig] = None,
                 *, batch_size: int = 8,
                 warmup_shapes: Optional[List[Tuple[int, int]]] = None):
        self.config = config or lingam_api.FitConfig(compaction="staged")
        self.batch_size = batch_size
        self._streams: Dict[str, stream_session.StreamSession] = {}
        self._next_sid = 0
        # Errors from the most recent flush_streams call (always kept,
        # telemetry on or off) — empty means every due refit landed.
        # Bounded: a pathological flush over many sessions cannot grow
        # the error record without limit (drops are counted).
        self.last_flush_errors: obs.BoundedRing = obs.BoundedRing(256)
        self.queries = query_lib.QueryEngine(
            batch_size=batch_size,
            backend=self.config.backend,
            tune=self.config.tune,
        )
        if warmup_shapes:
            self.warmup(warmup_shapes)

    def warmup(
        self,
        shapes: List[Tuple[int, int]],
        *,
        tune_mode: Optional[str] = None,
        compile: bool = True,
    ) -> Dict[str, object]:
        """Pre-resolve kernel plans (and pre-compile the fit programs)
        for the (m, d) dataset shapes this engine expects.

        With ``tune_mode="auto"`` (or ``FitConfig(tune="auto")``) the
        block-shape search runs *now*, per shape bucket, and persists to
        the user-local tuning overlay — so neither one-shot requests nor
        streaming refits ever pay a first-request search. Returns the
        resolved plans keyed by their tuning-table keys.
        """
        from repro.kernels.tune import autotune as ktune_autotune

        mode = tune_mode or self.config.tune
        # The fit path only routes through the chunked op when the
        # config bounds the moment pass; warm exactly what it will ask.
        warm_ops = ("pairwise_moments",) if (
            self.config.moment_chunk is None
        ) else ("pairwise_moments", "pairwise_moment_sums_chunked")
        plans = ktune_autotune.warmup_plans(
            shapes,
            ops=warm_ops,
            backend=self.config.backend,
            mode=mode,
            chunk=self.config.moment_chunk,
        )
        if compile and self.config.partition is None:
            for shape in shapes:
                lingam_batched.warmup_fit_many(shape, self.config)
        return plans

    def _bucket(self, n: int) -> int:
        return lingam_batched.pow2_bucket(n, self.batch_size)

    def _run_mesh(self, group: List[FitRequest]) -> None:
        """Mesh plan: one sharded full-fit program per dataset; the
        (m, d)-keyed compile cache lives in ``core.sharded``."""
        for r in group:
            res = lingam_api.fit_fn(
                jnp.asarray(np.asarray(r.data, np.float32)), self.config
            )
            r.result = lingam_api.FitResult(
                order=np.asarray(res.order),
                adjacency=np.asarray(res.adjacency),
                resid_var=np.asarray(res.resid_var),
            )

    def run(self, requests: List[FitRequest]) -> List[FitRequest]:
        with obs.span("serve.run", n=len(requests)):
            by_shape = {}
            for r in requests:
                by_shape.setdefault(np.asarray(r.data).shape, []).append(r)
            for shape, group in by_shape.items():
                if self.config.partition is not None:
                    self._run_mesh(group)
                    continue
                for start in range(0, len(group), self.batch_size):
                    chunk = group[start:start + self.batch_size]
                    self._run_fit_bucket(shape, chunk)
            obs_metrics.inc("serve.fit_requests", len(requests))
        return requests

    def _run_fit_bucket(self, shape, chunk: List[FitRequest]) -> None:
        bucket = self._bucket(len(chunk))
        with obs.span(
            "serve.fit_bucket", shape=shape, n=len(chunk), bucket=bucket
        ):
            t0 = time.perf_counter()
            xs = np.stack(
                [np.asarray(r.data, np.float32) for r in chunk]
                + [np.asarray(chunk[0].data, np.float32)]
                * (bucket - len(chunk))
            )
            results = lingam_batched.fit_many(
                jnp.asarray(xs), self.config
            )
            order = np.asarray(results.order)
            adj = np.asarray(results.adjacency)
            rv = np.asarray(results.resid_var)
            for i, r in enumerate(chunk):
                r.result = lingam_api.FitResult(
                    order=order[i], adjacency=adj[i], resid_var=rv[i]
                )
            obs_metrics.observe(
                "serve.bucket_fill", len(chunk) / bucket, kind="fit"
            )
            obs_metrics.observe(
                "serve.fit_bucket_s", time.perf_counter() - t0,
                m=shape[0], d=shape[1],
            )

    # ------------------------------------------------------------------
    # Streaming sessions
    # ------------------------------------------------------------------

    def open_stream(
        self, config: stream_session.StreamConfig
    ) -> str:
        """Admit a streaming session; returns its session id."""
        sid = f"stream-{self._next_sid}"
        self._next_sid += 1
        self._streams[sid] = stream_session.StreamSession(sid, config)
        return sid

    def post_chunk(
        self, sid: str, rows
    ) -> List[Tuple[str, stream_session.GraphDelta]]:
        """Advance a session's window by one chunk (O(chunk d^2), no
        fit). Auto-flushes — returning (sid, delta) pairs — once a full
        micro-batch of sessions is due, counting only sessions whose
        windows are full (a still-filling session cannot become due
        without its own posts, so it must not starve the active ones).
        A due refit is deferred at most one of its session's own posts
        waiting for peers to join the batch: if this session was
        already due *before* this post, the flush happens now, so a
        ready-but-idle peer delays an active client by one chunk at
        worst. Returns [] when nothing flushed (call
        :meth:`flush_streams` to force pending refits out)."""
        session = self._streams[sid]
        was_due = session.due
        session.post(rows)
        n_due = sum(1 for s in self._streams.values() if s.due)
        n_ready = sum(
            1 for s in self._streams.values() if s.rolling.ready
        )
        if n_due and (was_due or n_due >= min(self.batch_size, n_ready)):
            return self.flush_streams()
        if session.due:
            # This post left its session due but waiting for bucket
            # peers — the one-chunk deferral the auto-flush rule allows.
            obs_metrics.inc("serve.flush_deferrals", sid=sid)
        return []

    def flush_streams(self) -> List[Tuple[str, stream_session.GraphDelta]]:
        """Execute every due session's refit, batched.

        Due sessions' :class:`~repro.stream.window.RefitPlan`s are
        bucketed by (residual shape, fit config); each bucket is padded
        to the power-of-two micro-batch and run as one
        ``fit_many_from_stats`` program — the streaming analogue of
        :meth:`run`'s shape bucketing.

        A failing session does **not** abort the flush: its error is
        recorded as a :class:`FlushError` in ``last_flush_errors`` (and
        counted in ``serve.flush_errors`` when telemetry is on), the
        remaining sessions proceed, and the failed session stays due so
        the next post or flush retries it. A whole-bucket program
        failure falls back to per-session refits, so one poisoned plan
        cannot starve its bucket peers.
        """
        self.last_flush_errors.clear()
        t_flush = time.perf_counter()
        due = [
            (sid, s) for sid, s in self._streams.items() if s.due
        ]
        out: List[Tuple[str, stream_session.GraphDelta]] = []
        with obs.span("serve.flush", n_due=len(due)):
            now = time.monotonic()
            for sid, s in due:
                waited = s.due_wait_s(now)
                if waited is not None:
                    obs_metrics.observe("serve.queue_wait_s", waited)
            buckets: Dict[object, List] = {}
            for sid, s in due:
                try:
                    plan = s.rolling.prepare_refit()
                except Exception as e:  # noqa: BLE001 — surfaced as data
                    self._flush_error(sid, "prepare", None, e)
                    continue
                key = stream_session.bucket_key(s, plan)
                buckets.setdefault(key, []).append((sid, s, plan))
            for (shape, config), group in buckets.items():
                for start in range(0, len(group), self.batch_size):
                    part = group[start:start + self.batch_size]
                    out.extend(self._flush_bucket(shape, config, part))
            obs_metrics.observe(
                "serve.flush_s", time.perf_counter() - t_flush
            )
            obs_metrics.inc("serve.flushes")
        return out

    def _flush_bucket(
        self, shape, config, part
    ) -> List[Tuple[str, stream_session.GraphDelta]]:
        """One padded ``fit_many_from_stats`` micro-batch of due
        sessions, with per-session error isolation."""
        bucket = self._bucket(len(part))
        pad = bucket - len(part)
        plans = [p for _, _, p in part] + [part[0][2]] * pad
        out: List[Tuple[str, stream_session.GraphDelta]] = []
        with obs.span(
            "serve.flush_bucket", shape=shape, n=len(part), bucket=bucket
        ):
            obs_metrics.observe(
                "serve.bucket_fill", len(part) / bucket, kind="flush"
            )
            try:
                results = lingam_batched.fit_many_from_stats(
                    jnp.stack([p.resid for p in plans]),
                    jnp.stack([p.resid_mean for p in plans]),
                    jnp.stack([p.resid_cov for p in plans]),
                    config,
                )
                order = np.asarray(results.order)
                adj = np.asarray(results.adjacency)
                rv = np.asarray(results.resid_var)
            except Exception as e:  # noqa: BLE001 — surfaced as data
                self._flush_error("*", "fit", (shape, config), e)
                for sid, s, _ in part:
                    try:
                        out.append((sid, s.refit_now()))
                    except Exception as e2:  # noqa: BLE001
                        self._flush_error(sid, "fit", (shape, config), e2)
                return out
            for i, (sid, s, plan) in enumerate(part):
                try:
                    fit = stream_window.finish_refit(
                        plan,
                        lingam_api.FitResult(
                            order=order[i], adjacency=adj[i],
                            resid_var=rv[i],
                        ),
                    )
                    out.append((sid, s.apply_fit(fit)))
                except Exception as e:  # noqa: BLE001
                    self._flush_error(sid, "finish", (shape, config), e)
        return out

    def _flush_error(self, sid, stage, bucket, error) -> None:
        err = FlushError(sid=sid, stage=stage, bucket=bucket, error=error)
        self.last_flush_errors.append(err)
        obs_metrics.inc("serve.flush_errors", sid=sid, stage=stage)

    # ------------------------------------------------------------------
    # Causal queries (effects / interventions / RCA)
    # ------------------------------------------------------------------

    def query(self, queries: List[object]) -> List[object]:
        """Answer a micro-batch of causal queries against fitted graphs.

        Accepts a mixed list of :class:`repro.infer.query.EffectQuery` /
        ``InterventionQuery`` / ``RCAQuery``. Each request's ``graph``
        may be a :class:`~repro.infer.query.FittedGraph`, a bare
        :class:`~repro.core.api.FitResult` (wrapped with centered-data
        defaults), or a *stream session id* — resolved here to the
        session's current estimate with observational moments pulled
        from its incremental store (no rows re-read). Execution is
        delegated to the :class:`~repro.infer.query.QueryEngine`:
        bucketed by (kind, shape), padded to the power-of-two
        micro-batch, one compiled device-parallel program per bucket.

        Session-backed graphs are re-snapshotted from the *live*
        session on every call (the resolved ``FittedGraph`` remembers
        its ``sid``), so a client that re-issues the same query object
        after more posts sees the current estimate, never a stale one.
        """
        with obs.span("serve.query", n=len(queries)):
            for q in queries:
                sid = (
                    q.graph if isinstance(q.graph, str)
                    else getattr(q.graph, "sid", None)
                )
                if sid is not None:
                    q.graph = query_lib.FittedGraph.from_session(
                        self._streams[sid]
                    )
            return self.queries.run(queries)

    def poll_alerts(
        self, sid: Optional[str] = None
    ) -> List[stream_session.monitor_lib.DriftAlert]:
        """Drain unread drift alerts, oldest first.

        ``sid`` scopes the drain to one session; None collects across
        every admitted session. Each alert is delivered exactly once
        here — the session's bounded ``alert_history`` keeps a copy for
        post-hoc review, and alerts that *triggered* a refit also
        travel on that refit's :class:`~repro.stream.session.GraphDelta`
        from :meth:`flush_streams`. Sessions without a monitor simply
        never yield alerts.
        """
        sessions = (
            [self._streams[sid]] if sid is not None
            else list(self._streams.values())
        )
        out: List[stream_session.monitor_lib.DriftAlert] = []
        for s in sessions:
            out.extend(s.unread_alerts.drain())
        if out:
            obs_metrics.inc("serve.alerts_polled", len(out))
        return out

    def stream_session(self, sid: str) -> stream_session.StreamSession:
        """The live session object (last_fit / last_delta / state)."""
        return self._streams[sid]

    def close_stream(self, sid: str) -> stream_session.StreamSession:
        """Retire a session, returning its final state."""
        return self._streams.pop(sid)
