"""Stein Variational Gradient Descent (Liu & Wang, 2016) in JAX.

Used for the paper's §4.1 evaluation: after DirectLiNGAM produces the
weighted adjacency, a Bayesian linear-SEM posterior is approximated with
SVGD particles and scored on held-out interventions (I-NLL / I-MAE).

    T(x) = x + eps * phi(x),
    phi(x) = E_{x'~q}[ k(x', x) grad_{x'} log p(x') + grad_{x'} k(x', x) ]

with an RBF kernel using the median heuristic.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def _rbf(particles):
    """RBF kernel matrix + grad wrt first arg, median-heuristic bandwidth.
    particles: (n, d). Returns (K (n, n), dK (n, d)) where
    dK[i] = sum_j grad_{x_i} k(x_i, x_j)."""
    n = particles.shape[0]
    diff = particles[:, None, :] - particles[None, :, :]  # (n, n, d)
    sq = jnp.sum(diff * diff, axis=-1)
    med = jnp.median(sq)
    h = jnp.sqrt(0.5 * med / jnp.log(n + 1.0) + 1e-8)
    k = jnp.exp(-sq / (2 * h * h))
    # repulsion: sum_j grad_{x_j} k(x_j, x_i) = sum_j (x_i - x_j)/h^2 * k_ij
    dk = jnp.einsum("ijd,ij->id", diff, k) / (h * h)
    return k, dk


@functools.partial(jax.jit, static_argnames=("logp", "n_steps"))
def svgd(
    particles: jnp.ndarray,
    logp: Callable[[jnp.ndarray], jnp.ndarray],
    n_steps: int = 500,
    step_size: float = 1e-2,
):
    """Run SVGD. particles: (n, d); logp maps (d,) -> scalar."""
    grad_logp = jax.vmap(jax.grad(logp))

    def body(parts, _):
        g = grad_logp(parts)  # (n, d)
        k, dk = _rbf(parts)
        phi = (k @ g + dk) / parts.shape[0]
        return parts + step_size * phi, None

    out, _ = jax.lax.scan(body, particles, None, length=n_steps)
    return out


def gaussian_sem_logp(b_adj, noise_scale, prior_scale=1.0):
    """log p(x) for the linear SEM x = B x + e with Laplace-ish prior on
    latents: returns a callable for SVGD over a single sample vector x."""
    d = b_adj.shape[0]
    eye = jnp.eye(d, dtype=b_adj.dtype)

    def logp(x):
        resid = (eye - b_adj) @ x
        ll = -0.5 * jnp.sum((resid / noise_scale) ** 2)
        prior = -0.5 * jnp.sum((x / prior_scale) ** 2)
        return ll + 1e-3 * prior

    return logp
