"""AdamW + schedules + global-norm clipping, built from scratch (no optax).

State dtype is configurable (``ArchConfig.optimizer_dtype``): bf16 moments
halve optimizer HBM for the 340B config (recorded in §Dry-run memory).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any            # first moment (param-shaped pytree)
    nu: Any            # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"

    def init(self, params) -> OptState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        if callable(self.lr):
            return self.lr(step)
        return jnp.float32(self.lr)

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, grad_norm)."""
        # Global-norm clip in fp32.
        sq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)
        )
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.state_dtype)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
            mhat = m32 / bc1
            vhat = v32 / bc2
            step_ = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay > 0 and p.ndim >= 2:  # no decay on norms
                step_ = step_ + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * step_
            return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v), gnorm


def cosine_warmup(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)

    return lr
