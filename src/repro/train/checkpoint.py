"""Atomic, mesh-elastic checkpointing.

Arrays are saved *logically unsharded* (gathered to host), so a restart may
use a different mesh/pod count — the trainer re-shards on restore. Writes
are atomic: a temp directory is populated and ``os.replace``d into place,
and a ``manifest.json`` carries step, config hash and data-pipeline state
so restarts are sample-exact. ``latest_step`` + ``restore`` implement
resume-from-latest after preemption or node failure.

(Production note: at 340B scale one would write per-host shards through a
parallel filesystem; the save format here keeps the same manifest/atomic
protocol at smoke scale.)
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flat(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, state: Any, extra: Optional[Dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flat(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "n_arrays": len(arrays), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any):
    """Restore into the structure (and shardings) of ``template``."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves_with_paths:
        key = "/".join(str(x) for x in p)
        arr = data[key]
        if hasattr(leaf, "sharding"):
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        new_leaves.append(arr)
    return treedef.unflatten(new_leaves), manifest
