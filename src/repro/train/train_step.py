"""Train-step factory: loss + grad + AdamW update, with microbatch
accumulation (lax.scan) and optional bf16 gradient compression.

With accumulation, the per-microbatch backward runs inside the scan and the
parameter all-reduce (DP axis) happens once on the accumulated grads —
XLA's latency-hiding scheduler overlaps it with the next microbatch's
compute when the launch scripts enable
``--xla_tpu_enable_async_collective_fusion`` flags (see launch/train.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.train.optimizer import AdamW, OptState


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_train_step(
    cfg: ArchConfig,
    optimizer: AdamW,
    *,
    accum_steps: int = 1,
    grad_dtype: str = "float32",  # "bfloat16" = compressed DP all-reduce
    moe_impl: str = "scatter",
):
    """Returns step(state, batch) -> (state, metrics). ``batch`` leaves have
    leading dim global_batch; with accumulation it is reshaped to
    (accum, micro, ...) and scanned."""

    def loss_fn(params, microbatch):
        return model_lib.lm_loss(cfg, params, microbatch, moe_impl=moe_impl)

    def grads_of(params, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if grad_dtype != "float32":
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.dtype(grad_dtype)), grads
                )
            return loss, grads

        def re(x):
            return x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:])

        micro = jax.tree.map(re, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            if grad_dtype != "float32":
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.dtype(grad_dtype)), grads
                )
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_sum + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(grad_dtype)), params
        )
        (grads, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.float32(0.0)), micro
        )
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * jnp.asarray(inv, g.dtype), grads)
        return loss_sum * inv, grads

    def step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        loss, grads = grads_of(state.params, batch)
        new_params, new_opt, gnorm = optimizer.update(
            grads, state.opt, state.params
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
        return TrainState(new_params, new_opt), metrics

    return step


def init_state(cfg: ArchConfig, optimizer: AdamW, key, max_seq: int = 0):
    params = model_lib.init_params(cfg, key, max_seq=max_seq)
    return TrainState(params=params, opt=optimizer.init(params))
