"""Fault-tolerant training loop.

Features (the 1000+-node posture, exercised at smoke scale by tests):
  * resume-from-latest checkpoint (node failure / preemption restart),
  * SIGTERM/SIGINT handler -> emergency checkpoint then clean exit,
  * periodic atomic checkpoints with data-pipeline state in the manifest,
  * per-step wall-clock watchdog (straggler detection: steps slower than
    ``watchdog_factor`` x the running median are logged loudly),
  * mesh-elastic restore: checkpoints are logically unsharded, the trainer
    re-shards onto whatever mesh it was constructed with.
"""

from __future__ import annotations

import logging
import signal
import statistics
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import TokenStream
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamW
from repro.train.train_step import TrainState, init_state, make_train_step

log = logging.getLogger("repro.trainer")


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        optimizer: Optional[AdamW] = None,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        accum_steps: int = 1,
        seed: int = 0,
        mesh=None,
        shardings: Optional[Dict] = None,
        watchdog_factor: float = 3.0,
        moe_impl: str = "scatter",
    ):
        self.cfg = cfg
        self.shape = shape
        self.optimizer = optimizer or AdamW(state_dtype=cfg.optimizer_dtype)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.seed = seed
        self.mesh = mesh
        self.watchdog_factor = watchdog_factor
        self._preempted = False

        step_fn = make_train_step(
            cfg, self.optimizer, accum_steps=accum_steps, moe_impl=moe_impl
        )
        if mesh is not None and shardings is not None:
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(shardings["state"], shardings["batch"]),
                out_shardings=(shardings["state"], None),
                donate_argnums=(0,),
            )
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # ---------------------------------------------------------- lifecycle
    def _install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("signal %s received -> emergency checkpoint", signum)
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not in main thread (tests)

    def init_or_restore(self) -> tuple[TrainState, int]:
        state = init_state(
            self.cfg,
            self.optimizer,
            jax.random.key(self.seed),
            max_seq=self.shape.seq_len,
        )
        start_step = 0
        if self.ckpt_dir:
            latest = ckpt_lib.latest_step(self.ckpt_dir)
            if latest is not None:
                state, manifest = ckpt_lib.restore(
                    self.ckpt_dir, latest, state
                )
                start_step = manifest["step"]
                log.info("restored checkpoint at step %d", start_step)
        return state, start_step

    # ---------------------------------------------------------- main loop
    def train(
        self,
        n_steps: int,
        log_every: int = 10,
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ):
        self._install_signal_handlers()
        state, start_step = self.init_or_restore()
        stream = TokenStream(
            self.cfg, self.shape, seed=self.seed, start_step=start_step
        )
        durations: list[float] = []
        losses = []
        step = start_step
        try:
            while step < n_steps and not self._preempted:
                batch = next(stream)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])  # blocks
                dt = time.perf_counter() - t0
                step += 1
                losses.append(loss)

                # straggler watchdog
                if len(durations) >= 5:
                    med = statistics.median(durations[-20:])
                    if dt > self.watchdog_factor * med:
                        log.warning(
                            "straggler step %d: %.3fs vs median %.3fs",
                            step, dt, med,
                        )
                durations.append(dt)

                if step % log_every == 0:
                    toks = self.shape.global_batch * self.shape.seq_len
                    log.info(
                        "step %d loss %.4f %.0f tok/s", step, loss,
                        toks / max(dt, 1e-9),
                    )
                if on_metrics:
                    on_metrics(step, {**metrics, "seconds": dt})
                if self.ckpt_dir and step % self.ckpt_every == 0:
                    ckpt_lib.save(
                        self.ckpt_dir, step, state, extra=stream.state()
                    )
            if self.ckpt_dir and (self._preempted or step >= n_steps):
                ckpt_lib.save(self.ckpt_dir, step, state, extra=stream.state())
        finally:
            stream.close()
        return state, step, losses
