"""Model throughput smoke benchmark: one train step + one decode step per
assigned LM architecture, plus one functional-core DirectLiNGAM fit per
``lingam_workloads`` cell (reduced shapes, CPU) — proves every workload is
runnable end-to-end and gives a relative cost profile."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_arch, list_archs
from repro.configs.lingam_workloads import WORKLOADS
from repro.core import api as lingam_api
from repro.models import model as model_lib
from repro.train.optimizer import AdamW
from repro.train.train_step import init_state, make_train_step

SHAPE = ShapeConfig("bench", "train", 64, 2)


def _run_lingam(quick: bool):
    """One ``api.fit_fn`` fit per workload cell (smoke-scaled in quick)."""
    rows = []
    for w in WORKLOADS.values():
        m = min(w.m, 2048) if quick else min(w.m, 16384)
        d = min(w.d, 16) if quick else min(w.d, 64)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.laplace(size=(m, d)).astype(np.float32))
        config = lingam_api.FitConfig(compaction="staged")
        res = lingam_api.fit_fn(x, config)  # compile
        jax.block_until_ready(res.adjacency)
        t0 = time.perf_counter()
        res = lingam_api.fit_fn(x, config)
        jax.block_until_ready(res.adjacency)
        dt = time.perf_counter() - t0
        rows.append({"arch": w.name, "m": m, "d": d, "fit_s": dt})
        print(f"bench_models,{w.name},m={m},d={d},fit_s={dt:.3f}")
    return rows


def run(quick: bool = True):
    rows = _run_lingam(quick)
    archs = list_archs() if not quick else list_archs()[:10]
    for arch in archs:
        cfg = get_arch(arch, smoke=True)
        opt = AdamW(lr=1e-3)
        state = init_state(cfg, opt, jax.random.key(0), max_seq=SHAPE.seq_len)
        step = jax.jit(make_train_step(cfg, opt))
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32
            ),
        }
        if cfg.family in ("audio", "vlm"):
            batch["frontend"] = jnp.asarray(
                rng.normal(size=(2, cfg.n_frontend_tokens, cfg.d_model)),
                jnp.float32,
            )
        state, _ = step(state, batch)  # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = (time.perf_counter() - t0) / reps
        tok_s = 2 * 64 / dt
        rows.append({"arch": arch, "train_step_s": dt, "tok_s": tok_s})
        print(f"bench_models,{arch},us_per_step={dt*1e6:.0f},tok_s={tok_s:.0f}")
    return rows
