"""Heuristic-vs-tuned kernel plans per shape bucket -> BENCH_kernels.json.

For each (op, shape) cell the autotuner measures its aligned,
VMEM-bounded candidate grid (through the real ops wrappers) and the row
reports the heuristic plan's time next to the tuned winner's — the
measured answer to "what did replacing the static ``_pick_blocks``
heuristic with the dispatch subsystem buy at this shape bucket".

Run via ``python -m benchmarks.run --only tune``; the harness mirrors
the result to repo-root ``BENCH_kernels.json``. Measurements land in a
throwaway overlay (the user tuning cache is not touched); on CPU the
Pallas cells run the interpreter, so treat those rows as plumbing
verification — the accelerator rows are the product numbers.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile


def run(quick: bool = True):
    from repro.kernels.tune import autotune, cache, registry

    if quick:
        cells = [
            ("pairwise_moments", "blocked", (512, 16), None),
            ("pairwise_moments", "blocked", (1024, 32), None),
            ("pairwise_moments", "pallas", (256, 16), None),
            ("pairwise_moment_sums_chunked", "blocked", (1024, 16), 256),
        ]
        repeats = 2
    else:
        cells = [
            ("pairwise_moments", "blocked", (2048, 64), None),
            ("pairwise_moments", "blocked", (8192, 128), None),
            ("pairwise_moments", "pallas", (1024, 64), None),
            ("pairwise_moment_sums_rows", "pallas", (64, 64, 2048), 512),
            ("pairwise_moment_sums_chunked", "blocked", (4096, 64), 512),
            ("fused_moment_sums", "pallas", (8, 64, 1024), None),
        ]
        repeats = 3

    overlay = os.path.join(
        tempfile.mkdtemp(prefix="repro-tune-"), "overlay.json"
    )
    table = cache.TuneTable(overlay_path_=overlay)
    rows = []
    for op, backend, shape, chunk in cells:
        tuned = autotune.autotune_op(
            op, shape, backend=backend, chunk=chunk,
            repeats=repeats, quick=quick, table=table,
        )
        heur = registry.dispatch_heuristic(
            op, shape, backend=backend, chunk=chunk
        )
        by_plan = {
            dataclasses.replace(m.plan, source=""): m.seconds
            for m in tuned.measurements
        }
        heur_s = by_plan.get(dataclasses.replace(heur, source=""))
        best_s = min(m.seconds for m in tuned.measurements)
        row = {
            "op": op,
            "backend": backend,
            "shape": list(shape),
            "bucket": cache.shape_bucket(op, shape),
            "device_kind": tuned.device_kind,
            "heuristic": {**heur.to_entry(), "us": (heur_s or 0.0) * 1e6},
            "tuned": {**tuned.best.to_entry(), "us": best_s * 1e6},
            "speedup_vs_heuristic": (
                heur_s / best_s if heur_s and best_s else 1.0
            ),
            "n_candidates": len(tuned.measurements),
        }
        rows.append(row)
        print(
            f"tune,op={op},backend={backend},shape={shape},"
            f"heur_us={row['heuristic']['us']:.1f},"
            f"tuned_us={row['tuned']['us']:.1f},"
            f"speedup={row['speedup_vs_heuristic']:.2f}"
        )
    return {"device_kind": registry.device_kind(), "rows": rows}
