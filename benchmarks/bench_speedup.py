"""Paper Fig. 2 analogue: runtime scaling of the causal-ordering
sub-procedure, sequential (numpy pair loop) vs parallel (vectorized jnp /
Pallas-interpret), over a (samples x dims) grid; plus the fraction of
total DirectLiNGAM runtime spent in ordering.

On this CPU container the "parallel" rows measure the vectorized
single-core implementations (the TPU speed-up story is the §Roofline
analysis); the *speed-up column still shows the algorithmic win* of batched
vectorization over the pair loop — the same effect the paper's GPU kernel
exploits (32x on an RTX 6000 Ada).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.baselines import sequential_lingam as seq
from repro.core.ordering import causal_order
from repro.data.simulate import simulate_lingam


def _time(fn, *args, reps=1):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True):
    grid = (
        [(1_000, 8), (1_000, 16), (5_000, 16), (5_000, 32)]
        if quick
        else [(10_000, 8), (10_000, 16), (10_000, 32), (50_000, 32),
              (10_000, 64), (100_000, 16)]
    )
    rows = []
    for m, d in grid:
        gt = simulate_lingam(m=m, d=d, seed=0)
        x = gt.data

        t_seq = _time(lambda: seq.causal_order_sequential(x))
        t_par = _time(
            lambda: causal_order(jax.numpy.asarray(x), backend="blocked")
        )
        t_pal = _time(
            lambda: causal_order(
                jax.numpy.asarray(x), backend="pallas", interpret=True
            )
        )
        # ordering fraction of the full sequential fit (paper: 96%)
        t0 = time.perf_counter()
        order = seq.causal_order_sequential(x)
        t_ord = time.perf_counter() - t0
        t0 = time.perf_counter()
        seq.ols_adjacency_sequential(x, order)
        t_reg = time.perf_counter() - t0
        frac = t_ord / (t_ord + t_reg)

        rows.append({
            "m": m, "d": d,
            "sequential_s": t_seq,
            "parallel_blocked_s": t_par,
            "parallel_pallas_interpret_s": t_pal,
            "speedup_blocked": t_seq / t_par,
            "ordering_fraction": frac,
        })
        print(
            f"bench_speedup,m={m},d={d},seq={t_seq:.3f}s,"
            f"par={t_par:.3f}s,speedup={t_seq/t_par:.1f}x,"
            f"ordering_frac={frac:.3f}"
        )
    return rows
