"""Paper §3.1: NOTEARS on the same simple layered-DAG simulations, best
F1 over the lambda grid {0.001, 0.005, 0.01, 0.05, 0.1} — the paper
reports F1 0.79+-0.2, recall 0.69+-0.2, SHD 2.52+-1.67, showing the
continuous-optimization method fails where DirectLiNGAM is exact.
GOLEM (paper §2.4) is included for completeness."""

from __future__ import annotations

import numpy as np

from repro.baselines.golem import golem_fit
from repro.baselines.ica_lingam import ICALiNGAM
from repro.baselines.notears import notears_fit
from repro.core import DirectLiNGAM
from repro.data.simulate import simulate_lingam

from benchmarks.bench_equivalence import f1_rec_shd

LAMS = (0.001, 0.005, 0.01, 0.05, 0.1)


def run(quick: bool = True, n_sims: int | None = None):
    n = n_sims or (5 if quick else 50)
    m, d = (2_000, 10) if quick else (10_000, 10)
    inner = 300 if quick else 500
    nt_f1, nt_rec, nt_shd = [], [], []
    dl_f1 = []
    gl_f1 = []
    ica_f1 = []
    for s in range(n):
        gt = simulate_lingam(m=m, d=d, seed=s)
        best = (-1.0, 0.0, float(d * d))
        for lam in LAMS:
            w = notears_fit(gt.data, lam=lam, inner_steps=inner, max_outer=8)
            f1, rec, shd = f1_rec_shd(w, gt.adjacency)
            if f1 > best[0]:
                best = (f1, rec, float(shd))
        nt_f1.append(best[0]); nt_rec.append(best[1]); nt_shd.append(best[2])
        dl = DirectLiNGAM(backend="blocked", prune_threshold=0.1).fit(gt.data)
        dl_f1.append(f1_rec_shd(dl.adjacency_, gt.adjacency)[0])
        g = golem_fit(gt.data, n_steps=1000 if quick else 3000)
        gl_f1.append(f1_rec_shd(g, gt.adjacency)[0])
        ica = ICALiNGAM(n_steps=200, prune_threshold=0.1).fit(gt.data)
        ica_f1.append(f1_rec_shd(ica.adjacency_, gt.adjacency)[0])
    res = {
        "n_sims": n,
        "notears_f1": float(np.mean(nt_f1)), "notears_f1_std": float(np.std(nt_f1)),
        "notears_recall": float(np.mean(nt_rec)),
        "notears_shd": float(np.mean(nt_shd)), "notears_shd_std": float(np.std(nt_shd)),
        "directlingam_f1": float(np.mean(dl_f1)),
        "golem_f1": float(np.mean(gl_f1)),
        "ica_lingam_f1": float(np.mean(ica_f1)),
    }
    print(
        f"bench_notears,n={n},"
        f"notears_f1={res['notears_f1']:.2f}+-{res['notears_f1_std']:.2f},"
        f"notears_shd={res['notears_shd']:.2f},"
        f"directlingam_f1={res['directlingam_f1']:.2f},"
        f"golem_f1={res['golem_f1']:.2f},"
        f"ica_lingam_f1={res['ica_lingam_f1']:.2f}"
    )
    return res
