"""Paper Fig. 4 / Table 2 analogue: VarLiNGAM on stock-like VAR(1) series
(d=487 full / reduced quick). Reports in/out-degree distribution summary of
theta_0 and the top-5 exerting / receiving nodes by total causal effect."""

from __future__ import annotations

import numpy as np

from repro.core import VarLiNGAM
from repro.data.simulate import simulate_var_stocks


def run(quick: bool = True):
    m, d = (1_500, 64) if quick else (4_000, 487)
    x, b0_true, m1_true = simulate_var_stocks(m=m, d=d, seed=0)
    model = VarLiNGAM(
        lags=1, backend="blocked", prune_method="adaptive_lasso",
        prune_threshold=0.05,
    ).fit(x)
    th0, th1 = model.adjacency_matrices_[0], model.adjacency_matrices_[1]

    adj = np.abs(th0) > 0.05
    in_deg = adj.sum(axis=1)
    out_deg = adj.sum(axis=0)
    # total causal effects (paper: top exerting / receiving)
    exert = np.abs(th0).sum(axis=0) + np.abs(th1).sum(axis=0)
    recv = np.abs(th0).sum(axis=1) + np.abs(th1).sum(axis=1)
    top_exert = np.argsort(-exert)[:5].tolist()
    top_recv = np.argsort(-recv)[:5].tolist()
    leaves = [int(i) for i in np.where(out_deg == 0)[0][:5]]

    # structural quality vs ground truth
    tp = np.sum(adj & (b0_true != 0))
    prec = tp / max(adj.sum(), 1)
    rec = tp / max((b0_true != 0).sum(), 1)

    res = {
        "d": d,
        "in_degree_mean": float(in_deg.mean()),
        "out_degree_mean": float(out_deg.mean()),
        "degree_symmetry": float(
            np.corrcoef(np.sort(in_deg), np.sort(out_deg))[0, 1]
        ),
        "top_exerting": top_exert,
        "top_receiving": top_recv,
        "leaf_nodes": leaves,
        "b0_precision": float(prec),
        "b0_recall": float(rec),
    }
    print(
        f"bench_stocks,d={d},in_deg={res['in_degree_mean']:.2f},"
        f"out_deg={res['out_degree_mean']:.2f},"
        f"b0_precision={prec:.2f},b0_recall={rec:.2f},"
        f"top_exert={top_exert},top_recv={top_recv}"
    )
    return res
