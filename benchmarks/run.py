"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,metric=value,...`` CSV lines per benchmark and mirrors
every benchmark's results to a repo-root ``BENCH_<artifact>.json`` file
— the machine-readable perf-trajectory artifacts CI and future sessions
diff (the kernel-autotuning sweep lands as ``BENCH_kernels.json``).
``--out`` optionally also writes one aggregate JSON.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (  # noqa: E402
    bench_bootstrap,
    bench_drift,
    bench_equivalence,
    bench_gene,
    bench_infer,
    bench_notears,
    bench_profile,
    bench_sharded,
    bench_speedup,
    bench_stocks,
    bench_stream,
    bench_tune,
)

BENCHES = {
    "speedup": bench_speedup.run,          # paper Fig. 2
    "equivalence": bench_equivalence.run,  # paper Fig. 3
    "notears": bench_notears.run,          # paper §3.1
    "gene": bench_gene.run,                # paper Table 1
    "stocks": bench_stocks.run,            # paper Fig. 4 / Table 2
    "bootstrap": bench_bootstrap.run,      # loop vs vmap-batched engine
    "sharded": bench_sharded.run,          # mesh-plan sweep vs 1-dev oracle
    "stream": bench_stream.run,            # rolling-window vs from-scratch
    "tune": bench_tune.run,                # heuristic vs tuned kernel plans
    "infer": bench_infer.run,              # batched queries vs per-query loop
    "drift": bench_drift.run,              # drift detection + refit savings
    "profile": bench_profile.run,          # cost accounting + roofline rows
}

# Benchmark name -> repo-root artifact stem (BENCH_<stem>.json).
ARTIFACTS = {name: name for name in BENCHES}
ARTIFACTS["tune"] = "kernels"

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--profile", action="store_true",
                    help="enable repro.obs.profile for every bench and "
                         "stamp artifact rows with captured cost fields "
                         "(flops/bytes/utilization)")
    ap.add_argument("--out", type=str, default=None,
                    help="optional aggregate JSON (per-bench artifacts "
                         "always land as repo-root BENCH_*.json)")
    args = ap.parse_args()

    from repro.obs import profile as obs_profile  # noqa: E402,PLC0415

    if args.profile:
        obs_profile.enable()

    results = {}
    profiles = {}
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"=== bench:{name} ===")
        obs_profile.reset()
        try:
            results[name] = fn(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            results[name] = {"error": str(e)}
        if args.profile:
            profiles[name] = obs_profile.snapshot()
        print(f"=== bench:{name} done in {time.time()-t0:.1f}s ===\n")

    def default(o):
        import numpy as np

        if isinstance(o, (np.integer,)):
            return int(o)
        if isinstance(o, (np.floating,)):
            return float(o)
        raise TypeError(type(o))

    from repro import obs  # noqa: E402,PLC0415

    prov = obs.provenance(repo_root=_REPO_ROOT)

    def stamp_rows(payload: dict, snap: dict) -> dict:
        """Join captured cost records onto a payload's row dicts.

        A row matches a record on its ``op`` field (and, when both carry
        one, its ``shape``); matched rows gain flops/bytes/temp_bytes
        and the utilization columns. The full record table also lands
        under ``payload["profile"]`` so unjoined costs aren't dropped.
        """
        records = snap.get("records", [])
        by_op = {}
        for rec in records:
            by_op.setdefault(rec["op"], []).append(rec)

        def stamp(node):
            if isinstance(node, list):
                for item in node:
                    stamp(item)
            elif isinstance(node, dict):
                cands = by_op.get(node.get("op"), [])
                hit = None
                for rec in cands:
                    if "shape" in node and list(node["shape"]) != rec["shape"]:
                        continue
                    hit = rec
                    break
                if hit is not None:
                    for k in ("flops", "bytes", "temp_bytes",
                              "gflops_per_s", "gbytes_per_s",
                              "roofline_frac", "bound"):
                        if k in hit and k not in node:
                            node[k] = hit[k]
                for v in node.values():
                    stamp(v)

        stamp(payload.get("rows"))
        payload["profile"] = snap
        return payload

    def write_artifact(stem: str, payload: dict) -> None:
        """Mirror one benchmark's results to BENCH_<stem>.json at the
        repo root — the machine-readable perf-trajectory artifacts CI
        and future sessions diff. Each artifact is stamped with run
        provenance (device kind, jax version, git sha, timestamp) so a
        regression report can say *what* produced the numbers."""
        out = os.path.join(_REPO_ROOT, f"BENCH_{stem}.json")
        with open(out, "w") as f:
            json.dump(
                {
                    "bench": stem,
                    "quick": not args.full,
                    "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                    "provenance": prov,
                    **payload,
                },
                f, indent=1, default=default,
            )
        print(f"wrote {out}")

    for name, res in results.items():
        if isinstance(res, dict) and "error" in res:
            continue
        payload = res if isinstance(res, dict) else {"rows": res}
        if args.profile and name in profiles:
            payload = stamp_rows(dict(payload), profiles[name])
        write_artifact(ARTIFACTS[name], payload)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=default)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
