"""Bootstrap throughput: host-loop refits vs the vmap-batched engine.

Measures ``bootstrap_lingam`` end to end (resample, refit, edge stats)
for both strategies on cells derived from the ``lingam_workloads`` grid
(scaled to CPU-feasible sizes in quick mode). The vmap engine runs every
resample inside one compiled program and orders with in-trace staged
compaction — the "many fits fast" product of this repo; the loop path is
the legacy per-resample host loop. Both draw identical resample indices,
so the speedup column compares equal statistical work.

Headline cell (acceptance): (m=1024, d=64, n_sampling=20) — the vmap
engine must show >= 2x throughput over the loop path on CPU.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.lingam_workloads import WORKLOADS
from repro.core.bootstrap import bootstrap_lingam
from repro.data.simulate import simulate_lingam


def _cells(quick: bool):
    """(name, m, d, n_sampling) grid: workload-derived, CPU-scaled."""
    if quick:
        return [
            ("lingam-1m-100/quick", 1024, 64, 20),   # acceptance cell
            ("varlingam-stocks-487/quick", 2048, 32, 20),
        ]
    cells = []
    for w in WORKLOADS.values():
        cells.append((w.name, min(w.m, 8192), min(w.d, 128), 20))
    return cells


def run(quick: bool = True):
    rows = []
    for name, m, d, n_sampling in _cells(quick):
        gt = simulate_lingam(m=m, d=d, seed=0)
        x = gt.data

        common = dict(n_sampling=n_sampling, threshold=0.05, seed=0)
        # Warm both compile caches before timing.
        bootstrap_lingam(x, strategy="vmap", **common)
        bootstrap_lingam(
            x, n_sampling=min(2, n_sampling), threshold=0.05, seed=0,
            strategy="loop",
        )

        t0 = time.perf_counter()
        res_v = bootstrap_lingam(x, strategy="vmap", **common)
        t_vmap = time.perf_counter() - t0

        t0 = time.perf_counter()
        res_l = bootstrap_lingam(x, strategy="loop", **common)
        t_loop = time.perf_counter() - t0

        agree = bool(np.array_equal(res_v.edge_prob, res_l.edge_prob))
        rows.append({
            "cell": name, "m": m, "d": d, "n_sampling": n_sampling,
            "loop_s": t_loop, "vmap_s": t_vmap,
            "loop_fits_per_s": n_sampling / t_loop,
            "vmap_fits_per_s": n_sampling / t_vmap,
            "speedup": t_loop / t_vmap,
            "edge_prob_agree": agree,
        })
        print(
            f"bench_bootstrap,cell={name},m={m},d={d},n={n_sampling},"
            f"loop={t_loop:.2f}s,vmap={t_vmap:.2f}s,"
            f"speedup={t_loop/t_vmap:.2f}x,agree={agree}"
        )
    return rows
