"""Causal-query throughput: batched micro-batches vs a per-query loop.

The query subsystem's claim is that a micro-batch of requests against
same-shape graphs costs one compiled device-parallel program, not b
sequential dispatches. Measured here per (d, kind):

  * **loop** — one jitted single-query call per request (block until
    ready each time): the per-query serving baseline.
  * **batched** — the same requests through
    :class:`repro.infer.query.QueryEngine` (one ``jit(vmap)`` program
    per bucket).

Both sides run through the engine — the serving surface a client
actually hits — so the loop pays its real per-request costs (bucketing,
host-device transfer, dispatch, result materialization) just like the
batched path pays its stacking; the bare per-query kernel time is
recorded alongside (``loop_kernel_s``) as the compute floor. Cells:
total-effect queries at d in {64, 256}, plus an RCA cell (d=64,
256-row samples per request). Compile time is excluded from both sides
(one warm-up pass each); ``BENCH_infer.json`` records the per-query
times and the batched-vs-loop speedup.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.infer import effects, query


def _synthetic_graphs(d: int, n: int, seed: int):
    """n fitted-graph stand-ins: random strictly-lower-triangular (in a
    random order) adjacencies — the query path only reads the pytree."""
    from repro.core import api

    rng = np.random.default_rng(seed)
    graphs = []
    for _ in range(n):
        perm = rng.permutation(d).astype(np.int32)
        b_ord = np.tril(rng.normal(size=(d, d)) * 0.3, k=-1)
        inv = np.empty(d, dtype=np.int32)
        inv[perm] = np.arange(d, dtype=np.int32)
        b = b_ord[np.ix_(inv, inv)].astype(np.float32)
        graphs.append(api.FitResult(
            order=jnp.asarray(perm),
            adjacency=jnp.asarray(b),
            resid_var=jnp.ones((d,), jnp.float32),
        ))
    return graphs


def _time(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    dims = (64, 256)
    n_queries = 32 if quick else 64
    repeats = 3 if quick else 5
    rows = []

    @jax.jit
    def _one_effects(adj, order):
        return effects.total_effects_impl(adj, order)

    for d in dims:
        graphs = _synthetic_graphs(d, n_queries, seed=d)
        engine = query.QueryEngine(batch_size=n_queries)

        # Warm every path (compile excluded from the measurement).
        jax.block_until_ready(
            _one_effects(graphs[0].adjacency, graphs[0].order)
        )
        engine.run([query.EffectQuery(graph=g) for g in graphs])
        engine.run([query.EffectQuery(graph=graphs[0])])

        def loop():
            for g in graphs:
                engine.run([query.EffectQuery(graph=g)])

        def loop_kernel():
            for g in graphs:
                jax.block_until_ready(
                    _one_effects(g.adjacency, g.order)
                )

        def batched():
            engine.run([query.EffectQuery(graph=g) for g in graphs])

        t_loop = _time(loop, repeats)
        t_kernel = _time(loop_kernel, repeats)
        t_batched = _time(batched, repeats)
        speedup = t_loop / t_batched
        rows.append({
            "kind": "effects", "d": d, "n_queries": n_queries,
            "loop_s": t_loop, "loop_kernel_s": t_kernel,
            "batched_s": t_batched,
            "per_query_us_loop": 1e6 * t_loop / n_queries,
            "per_query_us_batched": 1e6 * t_batched / n_queries,
            "speedup": speedup,
        })
        print(f"infer,kind=effects,d={d},n={n_queries},"
              f"loop_s={t_loop:.4f},kernel_s={t_kernel:.4f},"
              f"batched_s={t_batched:.4f},speedup={speedup:.2f}")

    # RCA cell: attribution of a row batch per request.
    d, n_rows = 64, 256
    graphs = _synthetic_graphs(d, n_queries, seed=1)
    sample_rows = [
        np.random.default_rng(i).normal(size=(n_rows, d)).astype(np.float32)
        for i in range(n_queries)
    ]
    engine = query.QueryEngine(batch_size=n_queries)

    def rca_queries():
        return [
            query.RCAQuery(graph=g, rows=r, target=0)
            for g, r in zip(graphs, sample_rows)
        ]

    engine.run(rca_queries())  # warm-up

    def rca_loop():
        for q in rca_queries():
            engine.run([q])

    def rca_batched():
        engine.run(rca_queries())

    engine.run([rca_queries()[0]])  # warm the singleton bucket too
    t_loop = _time(rca_loop, repeats)
    t_batched = _time(rca_batched, repeats)
    rows.append({
        "kind": "rca", "d": d, "n_queries": n_queries, "n_rows": n_rows,
        "loop_s": t_loop, "batched_s": t_batched,
        "per_query_us_loop": 1e6 * t_loop / n_queries,
        "per_query_us_batched": 1e6 * t_batched / n_queries,
        "speedup": t_loop / t_batched,
    })
    print(f"infer,kind=rca,d={d},n={n_queries},rows={n_rows},"
          f"loop_s={t_loop:.4f},batched_s={t_batched:.4f},"
          f"speedup={t_loop / t_batched:.2f}")

    return {
        "rows": rows,
        "speedup_effects": {
            str(r["d"]): r["speedup"] for r in rows if r["kind"] == "effects"
        },
    }


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    run(quick=True)
