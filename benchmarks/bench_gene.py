"""Paper Table 1 analogue: interventional gene-expression evaluation.

No Perturb-CITE-seq offline -> synthetic Perturb-seq-like generator with
the same protocol: train on 80% of interventions, hold out 20%, fit
DirectLiNGAM, then score held-out interventions with a Stein-VI (SVGD)
posterior over the SEM: I-NLL and I-MAE. The continuous-optimization
comparator (DCD-FG in the paper) is represented by NOTEARS+VI (same class
of method, available offline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.notears import notears_fit
from repro.core import DirectLiNGAM
from repro.data.simulate import simulate_gene_perturb
from repro.vi.svgd import svgd


def _interventional_scores(b_adj, x, targets, held_out, noise_scale):
    """Predict distribution of downstream genes under held-out interventions
    via the SEM x = Bx + e; score NLL and MAE on observed cells."""
    d = b_adj.shape[0]
    eye = np.eye(d)
    try:
        inv = np.linalg.inv(eye - b_adj)
    except np.linalg.LinAlgError:
        inv = np.linalg.pinv(eye - b_adj)
    nlls, maes = [], []
    for g in held_out:
        cells = x[targets == g]
        if len(cells) == 0:
            continue
        # do(x_g = v): propagate the intervention's mean effect
        v = float(np.mean(cells[:, g]))
        e_mean = np.zeros(d)
        e_mean[g] = v  # exogenous override at the intervened node
        mu = inv @ e_mean
        mu[g] = v
        var = noise_scale**2 * np.maximum((inv**2).sum(axis=1), 1e-6)
        nll = 0.5 * np.mean(
            np.log(2 * np.pi * var)[None, :]
            + (cells - mu[None, :]) ** 2 / var[None, :]
        )
        mae = np.mean(np.abs(cells.mean(axis=0) - mu))
        nlls.append(nll)
        maes.append(mae)
    return float(np.mean(nlls)), float(np.mean(maes))


def run(quick: bool = True):
    m, d, n_int = (4_000, 64, 16) if quick else (50_000, 961, 192)
    x, targets, b_true = simulate_gene_perturb(
        m=m, d=d, n_interventions=n_int, seed=0
    )
    rng = np.random.default_rng(0)
    held_out = rng.choice(n_int, size=max(2, n_int // 5), replace=False)
    train_mask = ~np.isin(targets, held_out)
    x_train = x[train_mask]

    results = {}
    for name, fit in (
        ("directlingam", lambda: DirectLiNGAM(
            backend="blocked", prune_method="adaptive_lasso",
            prune_kwargs=dict(lam=0.02),
        ).fit(x_train).adjacency_),
        ("notears", lambda: notears_fit(
            x_train[: min(len(x_train), 2000)], lam=0.05,
            inner_steps=200, max_outer=6,
        )),
    ):
        b = np.asarray(fit())
        # SVGD posterior over per-variable noise scale (log-space particle)
        resid = x_train - x_train @ b.T
        emp = np.std(resid, axis=0).mean()

        def logp(z, emp=emp):
            # posterior over global log-noise-scale given residuals
            s = jnp.exp(z[0])
            return -0.5 * ((s - emp) / (0.1 * emp + 1e-6)) ** 2

        parts = jax.random.normal(jax.random.key(0), (32, 1)) * 0.1 + float(
            np.log(emp + 1e-6)
        )
        parts = svgd(parts, logp, n_steps=200, step_size=1e-2)
        noise_scale = float(np.exp(np.asarray(parts).mean()))
        nll, mae = _interventional_scores(b, x, targets, held_out, noise_scale)
        results[name] = {"inll": nll, "imae": mae}
        print(f"bench_gene,{name},inll={nll:.3f},imae={mae:.3f},d={d}")
    return results
