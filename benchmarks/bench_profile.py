"""Cost-accounting rows: stage attribution + kernel utilization
-> BENCH_profile.json.

Runs the roofline attribution engine
(:func:`repro.analysis.report.live_attribution`) at a quick (or
paper-leaning) shape and emits its stage rows (ordering / pruning /
solve / full_fit: seconds, FLOPs, bytes, GFLOP/s, %-of-roofline) and
per-kernel-variant utilization rows. ``analysis/regress.py`` tracks the
``best_s`` / ``gflops_per_s`` columns; the cost columns are
provenance-style context (they move with the device-peaks registry, not
the code, so they are skip-listed from pass/fail).

Run via ``python -m benchmarks.run --only profile``. On CPU the
roofline fractions are against the placeholder cpu-generic peaks —
comparative, not certified; calibrate with ``REPRO_PEAKS``.
"""

from __future__ import annotations


def run(quick: bool = True):
    from repro.analysis import report
    from repro.obs import profile

    profile.reset()
    m, d = (512, 16) if quick else (2048, 64)
    payload = report.live_attribution(
        m, d, backend="blocked", repeats=2, include_pallas=quick,
    )
    for row in payload["rows"]:
        print(f"bench_profile,stage={row['stage']},"
              f"best_s={row['best_s']:.6f},"
              f"gflops_per_s={row['gflops_per_s']:.4f},"
              f"roofline_frac={row['roofline_frac']:.4f}")
    for row in payload["kernels"]:
        print(f"bench_profile,variant={row['variant']},"
              f"best_s={row['best_s']:.6f},"
              f"gflops_per_s={row['gflops_per_s']:.4f},"
              f"roofline_frac={row['roofline_frac']:.4f}")
    return payload
