"""Graph-health monitoring: detection delay, false alarms, refit savings.

Two questions about :mod:`repro.stream.monitor`, measured on simulated
VAR(1)+LiNGAM streams with known structural breaks
(:func:`repro.data.simulate.simulate_var_breaks`):

  * **Does it see real breaks, and how fast?** For each break kind
    (edge flip, weight shift, noise-scale change) a monitored session
    streams across the break; we record whether an alert fired after
    the break and how many chunks later (detection delay), plus the
    false-alarm rate on the stationary pre-break stretch.
  * **What does adaptive cadence save?** The same stationary stream is
    served twice — fixed cadence (refit every ``refit_every`` chunks)
    vs adaptive coasting (interval doubles while the monitor reads
    stable) — and the wall time spent in refits is compared. Coasting
    trades nothing away on detection: an alert makes the session due
    immediately regardless of where the coast interval stands.

Emits ``BENCH_drift.json`` via ``benchmarks.run`` (tracked by
``analysis/regress.py``: the ``*_refit_s`` timings and the adaptive
speedup are the regression-gated metrics; detection delays and alarm
rates are reported for trend-watching, not gating).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.data.simulate import BREAK_KINDS, simulate_var_breaks
from repro.stream import MonitorConfig, StreamConfig, StreamSession


def _stream_config(d: int, chunk: int, window_chunks: int,
                   *, coast_max: int) -> StreamConfig:
    return StreamConfig(
        d=d, chunk=chunk, window_chunks=window_chunks,
        refit_every=2, coast_max=coast_max, monitor=MonitorConfig(),
    )


def _run_break(series: np.ndarray, at: int, cfg: StreamConfig) -> Dict:
    """Stream one broken series; returns detection + false-alarm facts."""
    chunk = cfg.chunk
    s = StreamSession("bench", cfg)
    detect_chunk = None
    fired_kinds: List[str] = []
    false_alarm_chunks = 0
    pre_chunks = 0
    n = (series.shape[0] // chunk) * chunk
    for ci, start in enumerate(range(0, n, chunk)):
        due = s.post(series[start:start + chunk])
        post_break = start + chunk > at
        pending = list(s.pending_alerts)
        if not post_break and s.monitor.armed:
            pre_chunks += 1
            if pending:
                false_alarm_chunks += 1
        if pending and post_break and detect_chunk is None:
            detect_chunk = ci
            fired_kinds = sorted({a.kind for a in pending})
        if due:
            s.refit_now()
    return {
        "detected": detect_chunk is not None,
        "delay_chunks": (
            None if detect_chunk is None else detect_chunk - at // chunk
        ),
        "fired_kinds": fired_kinds,
        "false_alarm_chunks": false_alarm_chunks,
        "pre_chunks": pre_chunks,
    }


def _run_cadence(series: np.ndarray, cfg: StreamConfig) -> Dict:
    """Stream one stationary series; returns refit count + wall time."""
    chunk = cfg.chunk
    s = StreamSession("bench", cfg)
    refit_s = 0.0
    n = (series.shape[0] // chunk) * chunk
    for start in range(0, n, chunk):
        if s.post(series[start:start + chunk]):
            t0 = time.perf_counter()
            s.refit_now()
            refit_s += time.perf_counter() - t0
    return {"n_refits": s.n_refits, "refit_s": refit_s,
            "final_cadence": s.cadence, "alerts": len(s.alert_history)}


def run(quick: bool = True):
    d = 12 if quick else 32
    chunk = 100 if quick else 200
    window_chunks = 8
    seeds = range(2) if quick else range(5)
    m = 6000 if quick else 12_000
    at = m // 2
    coast_max = 32

    # --- detection delay + false alarms per break kind ----------------
    per_kind: Dict[str, Dict] = {}
    fa_chunks = 0
    pre_chunks = 0
    for kind in BREAK_KINDS:
        delays, hits, runs = [], 0, 0
        kinds_union: set = set()
        for seed in seeds:
            br = simulate_var_breaks(
                m=m, d=d, kind=kind, seed=seed, at=at
            )
            out = _run_break(
                br.series, br.at,
                _stream_config(d, chunk, window_chunks,
                               coast_max=coast_max),
            )
            runs += 1
            fa_chunks += out["false_alarm_chunks"]
            pre_chunks += out["pre_chunks"]
            if out["detected"]:
                hits += 1
                delays.append(out["delay_chunks"])
                kinds_union.update(out["fired_kinds"])
        per_kind[kind] = {
            "detection_rate": hits / runs,
            "detect_delay_chunks": (
                float(np.mean(delays)) if delays else None
            ),
            "fired_kinds": sorted(kinds_union),
        }
    false_alarm_per_chunk = fa_chunks / max(pre_chunks, 1)

    # --- adaptive vs fixed cadence on a stationary stream -------------
    from repro.data.simulate import simulate_var_stocks

    series = simulate_var_stocks(m=m, d=d, seed=7)[0]
    fixed = _run_cadence(
        series, _stream_config(d, chunk, window_chunks, coast_max=0)
    )
    adaptive = _run_cadence(
        series,
        _stream_config(d, chunk, window_chunks, coast_max=coast_max),
    )

    res = {
        "d": d,
        "chunk": chunk,
        "window_chunks": window_chunks,
        "runs_per_kind": len(list(seeds)),
        "per_kind": per_kind,
        "false_alarm_per_chunk": false_alarm_per_chunk,
        "fixed_refits": fixed["n_refits"],
        "adaptive_refits": adaptive["n_refits"],
        "adaptive_final_cadence": adaptive["final_cadence"],
        "adaptive_alerts_stationary": adaptive["alerts"],
        "fixed_refit_s": fixed["refit_s"],
        "adaptive_refit_s": adaptive["refit_s"],
        "speedup_adaptive_cadence": (
            fixed["refit_s"] / max(adaptive["refit_s"], 1e-9)
        ),
    }
    delays_csv = ";".join(
        f"{k}={per_kind[k]['detect_delay_chunks']}" for k in BREAK_KINDS
    )
    print(
        f"bench_drift,d={d},chunk={chunk},"
        f"delays[{delays_csv}],"
        f"fa_per_chunk={false_alarm_per_chunk:.4f},"
        f"refits_fixed={fixed['n_refits']},"
        f"refits_adaptive={adaptive['n_refits']},"
        f"refit_s_fixed={fixed['refit_s']:.3f},"
        f"refit_s_adaptive={adaptive['refit_s']:.3f},"
        f"speedup={res['speedup_adaptive_cadence']:.2f}x"
    )
    return res
