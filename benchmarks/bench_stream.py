"""Streaming rolling-window VarLiNGAM vs from-scratch per-window refits.

Slides a chunked rolling window over synthetic S&P-like series (paper
§4.2 shapes: d=487 with --full) through the serving engine's streaming
sessions, and times each path end to end:

  * **rolling** — the streaming subsystem: incremental moment
    update/retract per slide, VAR from the merged covariance (no
    lstsq), chunk-accumulated ordering moments
    (``FitConfig.moment_chunk``), staged compaction, pruning +
    diagnostics from the moment state (``fit_from_stats``), due refits
    batched across sessions.
  * **scratch** — the status-quo per-window refit (the ``VarLiNGAM``
    facade path): window lstsq + ``fit_fn`` at the facade's defaults
    (full masked scan, data-pass pruning).
  * **scratch_same_config** — the ablation: the identical from-scratch
    pipeline but with the streaming fit config, isolating what the
    incremental statistics alone buy.

Reports per-slide wall seconds, the two speedup ratios, and adjacency
parity of the rolling estimates against the from-scratch oracle (the
tests pin the tight version of this against
``stream.window.direct_window_fit``).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import api
from repro.core.var_lingam import estimate_var
from repro.data.simulate import simulate_var_stocks
from repro.serve.engine import CausalDiscoveryEngine
from repro.stream import StreamConfig


def _scratch_window_fit(rows, lags, config):
    """The legacy per-window pipeline: lstsq VAR + full refit."""
    mats, _, resid = estimate_var(rows, lags)
    result = api.fit_fn(resid, config)
    b0 = np.asarray(result.adjacency)
    eye = np.eye(b0.shape[0], dtype=b0.dtype)
    thetas = [b0] + [
        np.asarray((eye - b0) @ mats[tau]) for tau in range(lags)
    ]
    return result, thetas


def run(quick: bool = True):
    d = 64 if quick else 487
    chunk = 128 if quick else 256
    window_chunks = 8
    lags = 1
    n_streams = 2
    n_slides = 3 if quick else 2
    stream_fit = api.FitConfig(
        backend="blocked", compaction="staged", moment_chunk=chunk
    )
    scratch_fit = api.FitConfig(backend="blocked")  # facade default plan

    cfg = StreamConfig(
        d=d, chunk=chunk, window_chunks=window_chunks, lags=lags,
        refit_every=1, fit=stream_fit,
    )
    n_warm = window_chunks + 2
    n_chunks = n_warm + n_slides
    series = [
        simulate_var_stocks(m=chunk * n_chunks + 8, d=d, seed=s)[0]
        for s in range(n_streams)
    ]

    # --- rolling path through the engine (batched due refits) --------
    eng = CausalDiscoveryEngine(batch_size=n_streams)
    sids = [eng.open_stream(cfg) for _ in range(n_streams)]

    def post_round(k):
        out = []
        for sid, x in zip(sids, series):
            out += eng.post_chunk(sid, x[k * chunk:(k + 1) * chunk])
        return out

    # Warm every compiled program the timed rounds will hit: the
    # stream-head window shape, the steady-state shape, and the
    # steady-state *pair* bucket; then drain pending dues so the timed
    # rounds start phase-aligned (one batched flush per round).
    for k in range(n_warm):
        post_round(k)
    eng.flush_streams()

    t0 = time.time()
    deltas = []
    for j in range(n_slides):
        deltas += post_round(n_warm + j)
    rolling_per_slide = (time.time() - t0) / (n_slides * n_streams)
    assert len(deltas) == n_slides * n_streams
    last = eng.stream_session(sids[0]).last_fit

    # --- scratch paths on stream 0's timed windows -------------------
    def window_rows(j):
        # Include the `lags` rows preceding the window so the scratch
        # VAR regresses exactly the window's rows (the rolling path
        # keeps that lag context via the ring's lead tail). Timed slide
        # j's window is chunks [n_warm + j - wc + 1, n_warm + j].
        start = (n_warm + 1 + j - window_chunks) * chunk
        return series[0][start - lags:start + window_chunks * chunk]

    _scratch_window_fit(window_rows(-1), lags, scratch_fit)  # warm
    t0 = time.time()
    scratch_results = [
        _scratch_window_fit(window_rows(j), lags, scratch_fit)
        for j in range(n_slides)
    ]
    scratch_per_window = (time.time() - t0) / n_slides

    _scratch_window_fit(window_rows(-1), lags, stream_fit)  # warm
    t0 = time.time()
    for j in range(n_slides):
        _scratch_window_fit(window_rows(j), lags, stream_fit)
    scratch_same_cfg = (time.time() - t0) / n_slides

    # --- parity of the final timed window ----------------------------
    sc_result, _ = scratch_results[-1]
    order_match = bool(
        np.array_equal(
            np.asarray(last.result.order), np.asarray(sc_result.order)
        )
    )
    adj_diff = float(
        np.abs(
            np.asarray(last.result.adjacency)
            - np.asarray(sc_result.adjacency)
        ).max()
    )

    res = {
        "d": d,
        "chunk": chunk,
        "window_chunks": window_chunks,
        "window_rows": window_chunks * chunk,
        "lags": lags,
        "streams": n_streams,
        "slides": n_slides,
        "rolling_per_slide_s": rolling_per_slide,
        "scratch_per_window_s": scratch_per_window,
        "scratch_same_config_s": scratch_same_cfg,
        "speedup_vs_scratch": scratch_per_window / rolling_per_slide,
        "speedup_same_config": scratch_same_cfg / rolling_per_slide,
        "order_match_last_window": order_match,
        "adj_max_diff_last_window": adj_diff,
        "edges_last_window": int(
            eng.stream_session(sids[0]).last_delta.n_edges
        ),
        "stream_fit": dataclasses.asdict(stream_fit),
    }
    print(
        f"bench_stream,d={d},window={window_chunks * chunk},chunk={chunk},"
        f"rolling={rolling_per_slide:.3f}s,"
        f"scratch={scratch_per_window:.3f}s,"
        f"same_cfg={scratch_same_cfg:.3f}s,"
        f"speedup={res['speedup_vs_scratch']:.2f}x,"
        f"speedup_same_cfg={res['speedup_same_config']:.2f}x,"
        f"order_match={order_match},adj_max_diff={adj_diff:.2e}"
    )
    return res
