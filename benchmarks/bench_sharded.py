"""Mesh-plan sweep: ordering/fit time per mesh shape vs the 1-device oracle.

Sweeps the mesh shapes 1x1, 2x2, 4x1, 8x1 over 8 forced host devices
(subprocess, so the parent process keeps its single default device) and
times, per shape:

  * the sharded ordering (``make_sharded_causal_order`` — the 96% hot
    path) and its per-step cost,
  * the full sharded fit through ``fit_fn`` with a ``Partition``
    (ordering with staged compaction + row-sharded pruning),

against the single-device ``causal_order`` oracle, reporting order
agreement. (Exact agreement is pinned by tests at controlled cells; at
arbitrary sizes a genuinely near-tied argmax step may resolve
differently between the local blocked kernel and the chunked row-tile
kernel — ``order_n_disagree`` makes that visible rather than failing.)
On forced host devices the collectives are memcpys, so this measures
plan overhead, not speedup — the point is the machine-readable perf
trajectory (``benchmarks.run`` mirrors these rows into
``BENCH_sharded.json`` at the repo root) that a real multi-chip run
slots into.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent(
    """
    import json, sys, time
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import api
    from repro.core.ordering import causal_order
    from repro.core.sharded import make_sharded_causal_order
    from repro.data.simulate import simulate_lingam
    from repro.launch.mesh import mesh_from_spec

    m, d, chunk = (int(a) for a in sys.argv[1:4])
    gt = simulate_lingam(m=m, d=d, seed=0)
    x = jnp.asarray(gt.data)

    causal_order(x).block_until_ready()  # compile
    t0 = time.perf_counter()
    ref = causal_order(x)
    ref.block_until_ready()
    t_oracle = time.perf_counter() - t0
    ref = np.asarray(ref)

    rows = []
    for shape in (
        (("data", 1), ("model", 1)),
        (("data", 2), ("model", 2)),
        (("data", 4), ("model", 1)),
        (("data", 8), ("model", 1)),
    ):
        sizes = dict(shape)
        label = f"{sizes['data']}x{sizes['model']}"
        mesh = mesh_from_spec(shape)
        fn, m_pad, d_pad = make_sharded_causal_order(mesh, m, d, chunk=chunk)
        x_pad = jnp.pad(x, ((0, m_pad - m), (0, d_pad - d)))
        fn(x_pad).block_until_ready()  # compile
        t0 = time.perf_counter()
        order = fn(x_pad)
        order.block_until_ready()
        t_order = time.perf_counter() - t0

        part = api.Partition(mesh=shape, chunk=chunk)
        cfg = api.FitConfig(compaction="staged", partition=part)
        api.fit_fn(x, cfg).adjacency.block_until_ready()  # compile
        t0 = time.perf_counter()
        res = api.fit_fn(x, cfg)
        res.adjacency.block_until_ready()
        t_fit = time.perf_counter() - t0

        got = np.asarray(order)[:d]
        rows.append({
            "mesh": label, "m": m, "d": d,
            "order_s": t_order, "order_step_ms": 1e3 * t_order / d,
            "fit_s": t_fit, "oracle_order_s": t_oracle,
            "order_matches_oracle": bool(np.array_equal(got, ref)),
            "order_n_disagree": int((got != ref).sum()),
        })
    print("BENCH_JSON:" + json.dumps(rows), flush=True)
    """
)


def run(quick: bool = True):
    m, d, chunk = (2048, 32, 256) if quick else (16384, 96, 512)
    env = dict(os.environ)
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(m), str(d), str(chunk)],
        capture_output=True, text=True, env=env, cwd=root, timeout=3600,
    )
    if out.returncode != 0:
        raise RuntimeError(f"bench_sharded subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    payload = next(
        line for line in out.stdout.splitlines()
        if line.startswith("BENCH_JSON:")
    )
    rows = json.loads(payload[len("BENCH_JSON:"):])
    for r in rows:
        print(
            f"bench_sharded,mesh={r['mesh']},m={r['m']},d={r['d']},"
            f"order={r['order_s']:.3f}s,step={r['order_step_ms']:.1f}ms,"
            f"fit={r['fit_s']:.3f}s,oracle={r['oracle_order_s']:.3f}s,"
            f"match={r['order_matches_oracle']},"
            f"n_disagree={r['order_n_disagree']}"
        )
    return rows
