"""Paper Fig. 3: parallel and sequential DirectLiNGAM produce the exact
same causal order, and both recover the simulated DAG (F1 / recall / SHD
over N seeds; paper uses 50 sims of m=10000, d=10)."""

from __future__ import annotations

import numpy as np

from repro.baselines import sequential_lingam as seq
from repro.core import DirectLiNGAM
from repro.data.simulate import simulate_lingam


def f1_rec_shd(b_est, b_true, thresh=0.1):
    e = np.abs(b_est) > thresh
    t = b_true != 0
    tp = np.sum(e & t)
    fp = np.sum(e & ~t)
    fn = np.sum(~e & t)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    return f1, rec, fp + fn


def run(quick: bool = True, n_sims: int | None = None):
    n = n_sims or (10 if quick else 50)
    m, d = (3_000, 8) if quick else (10_000, 10)
    matches, f1s, recs, shds = 0, [], [], []
    for s in range(n):
        gt = simulate_lingam(m=m, d=d, seed=s)
        o_seq = seq.causal_order_sequential(gt.data)
        model = DirectLiNGAM(backend="blocked", prune_threshold=0.1).fit(
            gt.data
        )
        matches += int(np.array_equal(o_seq, model.causal_order_))
        f1, rec, shd = f1_rec_shd(model.adjacency_, gt.adjacency)
        f1s.append(f1)
        recs.append(rec)
        shds.append(shd)
    res = {
        "n_sims": n,
        "order_match_rate": matches / n,
        "f1_mean": float(np.mean(f1s)), "f1_std": float(np.std(f1s)),
        "recall_mean": float(np.mean(recs)),
        "shd_mean": float(np.mean(shds)), "shd_std": float(np.std(shds)),
    }
    print(
        f"bench_equivalence,n={n},order_match={res['order_match_rate']:.2f},"
        f"f1={res['f1_mean']:.3f}+-{res['f1_std']:.3f},"
        f"recall={res['recall_mean']:.3f},shd={res['shd_mean']:.2f}"
    )
    return res
