"""Optimizer, checkpoint (atomic/elastic/resume), trainer fault tolerance,
data pipeline determinism, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch
from repro.data.pipeline import TokenStream
from repro.models import model as model_lib
from repro.serve.engine import Request, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamW, cosine_warmup
from repro.train.trainer import Trainer
from repro.train.train_step import init_state, make_train_step

SHAPE = ShapeConfig("tiny", "train", 32, 4)


def test_adamw_reduces_loss():
    cfg = get_arch("qwen3-1.7b", smoke=True)
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    state = init_state(cfg, opt, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, opt))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 500, (4, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 500, (4, 32)), jnp.int32),
    }
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_accumulation_matches_full_batch():
    cfg = get_arch("qwen2-1.5b", smoke=True)
    opt = AdamW(lr=1e-3)
    state = init_state(cfg, opt, jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 500, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 500, (8, 16)), jnp.int32),
    }
    s1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))
    s4 = jax.jit(make_train_step(cfg, opt, accum_steps=4))
    _, m1 = s1(state, batch)
    _, m4 = s4(state, batch)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m4["loss"]), rtol=1e-4
    )
    np.testing.assert_allclose(
        float(m1["grad_norm"]), float(m4["grad_norm"]), rtol=1e-3
    )


def test_cosine_warmup_schedule():
    lr = cosine_warmup(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg = get_arch("qwen3-1.7b", smoke=True)
    opt = AdamW()
    state = init_state(cfg, opt, jax.random.key(2))
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 7, state, extra={"seed": 0, "step": 7})
    assert ckpt.latest_step(d) == 7
    restored, manifest = ckpt.restore(d, 7, state)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # no .tmp residue
    assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


def test_trainer_resume_is_sample_exact(tmp_path):
    cfg = get_arch("qwen3-1.7b", smoke=True)
    d = str(tmp_path / "ck")
    # run 6 steps with checkpoint every 3
    t1 = Trainer(cfg, SHAPE, ckpt_dir=d, ckpt_every=3, seed=7)
    state1, step1, losses1 = t1.train(n_steps=6, log_every=100)
    # fresh trainer restarts from step 6 checkpoint and continues
    t2 = Trainer(cfg, SHAPE, ckpt_dir=d, ckpt_every=3, seed=7)
    state2, step2, losses2 = t2.train(n_steps=8, log_every=100)
    assert step2 == 8 and len(losses2) == 2
    # one uninterrupted run must match the resumed run exactly
    t3 = Trainer(cfg, SHAPE, ckpt_dir=str(tmp_path / "ck3"), ckpt_every=100,
                 seed=7)
    _, _, losses3 = t3.train(n_steps=8, log_every=100)
    np.testing.assert_allclose(losses3[6:], losses2, rtol=1e-5)


def test_pipeline_deterministic_and_restartable():
    cfg = get_arch("qwen3-1.7b", smoke=True)
    s1 = TokenStream(cfg, SHAPE, seed=3, start_step=0)
    batches1 = [next(s1) for _ in range(4)]
    s1.close()
    s2 = TokenStream(cfg, SHAPE, seed=3, start_step=2)
    batches2 = [next(s2) for _ in range(2)]
    s2.close()
    np.testing.assert_array_equal(
        batches1[2]["tokens"], batches2[0]["tokens"]
    )
    np.testing.assert_array_equal(
        batches1[3]["labels"], batches2[1]["labels"]
    )


def test_serve_engine_greedy_matches_forward():
    cfg = get_arch("qwen3-1.7b", smoke=True).replace(compute_dtype="float32")
    params = model_lib.init_params(cfg, jax.random.key(5), max_seq=32)
    eng = ServeEngine(cfg, params, batch_size=2, max_seq=32)
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(1, cfg.vocab_size, 8).astype(np.int32) for _ in range(2)
    ]
    reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    eng.generate(reqs)
    for r in reqs:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
    # same-length prompts => decode must equal argmax of teacher-forced run
    seq = np.concatenate([prompts[0], np.asarray(reqs[0].out_tokens[:-1])])
    logits, _ = model_lib.forward(cfg, params, jnp.asarray(seq[None]))
    greedy = np.argmax(
        np.asarray(logits[0, len(prompts[0]) - 1:, : cfg.vocab_size]), -1
    )
    np.testing.assert_array_equal(greedy[: len(reqs[0].out_tokens)],
                                  reqs[0].out_tokens)


def test_svgd_matches_gaussian_posterior():
    from repro.vi.svgd import svgd

    # target: N(2, 0.5^2) in 1-D; particles should match mean/var
    def logp(x):
        return -0.5 * jnp.sum(((x - 2.0) / 0.5) ** 2)

    parts = jax.random.normal(jax.random.key(0), (64, 1))
    out = svgd(parts, logp, n_steps=400, step_size=5e-2)
    assert abs(float(jnp.mean(out)) - 2.0) < 0.15
    assert abs(float(jnp.std(out)) - 0.5) < 0.15
