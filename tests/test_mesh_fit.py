"""Mesh execution plan == local plan, bit for bit.

``fit_fn`` with a ``Partition`` compiles the full fit (ordering with
optional staged compaction, pruning, diagnostics) to one ``shard_map``
program; these tests pin its ``FitResult`` leaves to be *bit-identical*
to the local plan's across mesh shapes, compaction modes, backends, and
padding edge cases.

Multi-device cases run in a subprocess with XLA_FLAGS forcing 8 host
devices so the main test process keeps seeing exactly 1 device (per the
dry-run contract); the degenerate 1 x 1 mesh runs in-process.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


def test_mesh_1x1_bit_identical_in_process():
    """The degenerate mesh plan (1 x 1) on the default device."""
    import jax.numpy as jnp

    from repro.core import api
    from repro.data.simulate import simulate_lingam

    gt = simulate_lingam(m=250, d=9, seed=0)
    x = jnp.asarray(gt.data)
    part = api.Partition(mesh=(("data", 1), ("model", 1)), chunk=64)
    for compaction in ("none", "staged"):
        cfg = api.FitConfig(compaction=compaction, min_stage=3)
        ref = api.fit_fn(x, cfg)
        got = api.fit_fn(
            x, api.FitConfig(compaction=compaction, min_stage=3,
                             partition=part)
        )
        assert np.array_equal(np.asarray(ref.order), np.asarray(got.order))
        assert np.array_equal(
            np.asarray(ref.adjacency), np.asarray(got.adjacency)
        )
        assert np.array_equal(
            np.asarray(ref.resid_var), np.asarray(got.resid_var)
        )


def test_batched_engine_rejects_partition():
    """vmap and mesh plans are orthogonal; nesting must fail loudly."""
    import jax.numpy as jnp

    from repro.core import api, batched

    part = api.Partition(mesh=(("data", 1), ("model", 1)))
    with pytest.raises(ValueError, match="mesh partition"):
        batched.fit_many(
            jnp.zeros((2, 64, 4)), api.FitConfig(partition=part)
        )


_PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import api
    from repro.data.simulate import simulate_lingam

    def leaves_equal(a, b):
        return (
            np.array_equal(np.asarray(a.order), np.asarray(b.order))
            and np.array_equal(
                np.asarray(a.adjacency), np.asarray(b.adjacency))
            and np.array_equal(
                np.asarray(a.resid_var), np.asarray(b.resid_var))
        )

    # Acceptance cell: (m=256, d=24), every mesh shape x compaction mode.
    gt = simulate_lingam(m=256, d=24, seed=0)
    x = jnp.asarray(gt.data)
    shapes = [
        ((("data", 1), ("model", 1))),
        ((("data", 2), ("model", 2))),
        ((("data", 4), ("model", 1))),
        ((("data", 8), ("model", 1))),
    ]
    for compaction in ("none", "staged"):
        ref = api.fit_fn(x, api.FitConfig(compaction=compaction))
        for shape in shapes:
            part = api.Partition(mesh=shape, chunk=64)
            got = api.fit_fn(
                x, api.FitConfig(compaction=compaction, partition=part))
            assert leaves_equal(ref, got), (shape, compaction)
            print("OK", dict(shape), compaction, flush=True)

    # Pallas row-tile kernel (interpret) under shard_map.
    for compaction in ("none", "staged"):
        ref = api.fit_fn(
            x, api.FitConfig(backend="pallas", compaction=compaction))
        got = api.fit_fn(x, api.FitConfig(
            backend="pallas", compaction=compaction,
            partition=api.Partition(mesh=(("data", 2), ("model", 2)),
                                    chunk=64),
        ))
        assert leaves_equal(ref, got), ("pallas", compaction)
        print("OK pallas", compaction, flush=True)

    # Non-divisible m/d: both axes need padding (d=23 over 2 pair
    # shards, m=250 over 2 x chunk=32 sample slots), OLS and lasso.
    gt = simulate_lingam(m=250, d=23, seed=2)
    x = jnp.asarray(gt.data)
    part = api.Partition(mesh=(("data", 2), ("model", 2)), chunk=32)
    for kw in (
        dict(),
        dict(prune_method="adaptive_lasso",
             prune_kwargs=dict(lam=0.05), prune_threshold=0.02),
    ):
        ref = api.fit_fn(
            x, api.FitConfig(compaction="staged", min_stage=4, **kw))
        got = api.fit_fn(x, api.FitConfig(
            compaction="staged", min_stage=4, partition=part, **kw))
        assert leaves_equal(ref, got), kw
        print("OK nondivisible", sorted(kw), flush=True)

    # Fully sharded finish (gather_finish=False): the dataset is never
    # reassembled, so the covariance reduction order differs — same
    # order, coefficients to fp32 reduction-order tolerance.
    scaled = api.Partition(
        mesh=(("data", 2), ("model", 2)), chunk=32, gather_finish=False)
    for kw in (
        dict(),
        dict(prune_method="adaptive_lasso",
             prune_kwargs=dict(lam=0.05), prune_threshold=0.02),
    ):
        ref = api.fit_fn(
            x, api.FitConfig(compaction="staged", min_stage=4, **kw))
        got = api.fit_fn(x, api.FitConfig(
            compaction="staged", min_stage=4, partition=scaled, **kw))
        assert np.array_equal(np.asarray(ref.order), np.asarray(got.order))
        # FISTA (400 iters) amplifies the psum reduction-order ulps, so
        # the lasso path needs a looser (still fp32-tight) tolerance.
        np.testing.assert_allclose(
            np.asarray(ref.adjacency), np.asarray(got.adjacency),
            atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(
            np.asarray(ref.resid_var), np.asarray(got.resid_var),
            atol=1e-4, rtol=1e-3)
        print("OK sharded-finish", sorted(kw), flush=True)
    print("MESH_FIT_OK")
    """
)


_ROUTING_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import api, VarLiNGAM
    from repro.serve.engine import CausalDiscoveryEngine, FitRequest
    from repro.data.simulate import simulate_lingam, simulate_var_stocks

    # Routing, not plan parity (the parity script pins bit-identity at
    # controlled cells): a partitioned config handed to a facade/engine
    # must produce exactly what the mesh plan produces directly —
    # fit_fn with the same config on the same data, bit for bit.
    part = api.Partition(mesh=(("data", 4), ("model", 2)), chunk=64)
    cfg = api.FitConfig(compaction="staged", partition=part)

    def assert_same_fit(result, data):
        direct = api.fit_fn(jnp.asarray(data, jnp.float32), cfg)
        assert np.array_equal(result.order, np.asarray(direct.order))
        assert np.array_equal(
            result.adjacency, np.asarray(direct.adjacency))
        assert np.array_equal(
            result.resid_var, np.asarray(direct.resid_var))

    # Engine: partitioned configs bypass the vmap micro-batcher and run
    # per-dataset through the mesh plan (shape-bucketed compile reuse).
    datasets = [simulate_lingam(m=256, d=12, seed=s).data for s in range(3)]
    mesh_eng = CausalDiscoveryEngine(cfg)
    for req in mesh_eng.run([FitRequest(data=d) for d in datasets]):
        assert_same_fit(req.result, req.data)
        assert sorted(req.result.order.tolist()) == list(range(12))
    print("OK engine", flush=True)

    # VarLiNGAM: the facade's residual ordering runs on the mesh; its
    # result_ must equal the mesh plan applied to its own VAR residuals,
    # and the recovered structure must match the local facade's quality.
    x, b0, m1 = simulate_var_stocks(m=2000, d=10, edge_prob=0.2, seed=0)
    v_mesh = VarLiNGAM(
        lags=1, prune_threshold=0.1, compaction="staged", partition=part
    ).fit(x)
    direct = api.fit_fn(
        jnp.asarray(v_mesh.residuals_, jnp.float32),
        v_mesh.to_config(),
    )
    assert np.array_equal(v_mesh.causal_order_, np.asarray(direct.order))
    assert np.array_equal(
        v_mesh.adjacency_matrices_[0], np.asarray(direct.adjacency))
    true_edges = b0 != 0
    est_edges = np.abs(v_mesh.adjacency_matrices_[0]) > 0.1
    tp = (true_edges & est_edges).sum()
    assert tp >= 0.6 * true_edges.sum(), (tp, true_edges.sum())
    print("OK varlingam", flush=True)
    print("MESH_ROUTING_OK")
    """
)


def _run_subprocess(script, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=timeout,
    )


@pytest.mark.slow
def test_mesh_fit_bit_identical_to_local():
    """Acceptance: mesh partition on 8 forced host devices returns
    bit-identical FitResult leaves to the local plan across mesh shapes,
    compaction modes, backends, and padding edges."""
    out = _run_subprocess(_PARITY_SCRIPT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_FIT_OK" in out.stdout


@pytest.mark.slow
def test_mesh_routing_engine_and_varlingam():
    """VarLiNGAM and CausalDiscoveryEngine route through the mesh plan."""
    out = _run_subprocess(_ROUTING_SCRIPT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_ROUTING_OK" in out.stdout
