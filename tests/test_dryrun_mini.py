"""Dry-run integration test on a subprocess debug mesh (8 host devices):
lower+compile representative cells of each kind — train (dense), decode
(ssm), prefill (enc-dec audio) — plus the sharded LiNGAM ordering, on both
a 2-axis and a 3-axis (pod) mesh."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.launch.dryrun import lower_lm_cell
    from repro.launch.mesh import make_debug_mesh
    from repro.core.sharded import make_sharded_causal_order

    cells = [
        ("qwen3-1.7b", "train_4k"),
        ("mamba2-2.7b", "decode_32k"),
        ("whisper-base", "prefill_32k"),
    ]
    for pod in (0, 2):
        mesh = make_debug_mesh(2, 2, pod=pod) if pod else make_debug_mesh(4, 2)
        for arch, shape in cells:
            with mesh:
                lowered, aux = lower_lm_cell(arch, shape, mesh)
            compiled = lowered.compile()
            txt = compiled.as_text()
            assert len(txt) > 0
            print(f"OK {arch} {shape} pod={pod}", flush=True)
        fn, m_pad, d_pad = make_sharded_causal_order(
            mesh, 1024, 32,
            sample_axes=("pod", "data") if pod else ("data",), chunk=256,
        )
        x = jax.ShapeDtypeStruct((m_pad, d_pad), jax.numpy.float32)
        with mesh:
            fn.lower(x).compile()
        print(f"OK lingam pod={pod}", flush=True)
    print("DRYRUN_MINI_OK")
    """
)


@pytest.mark.slow
def test_dryrun_mini_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "DRYRUN_MINI_OK" in out.stdout
