"""GQA attention vs a naive per-head reference; RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import layers


def _naive_gqa(q, k, v, causal=True):
    """Per-head python-loop attention oracle. q: (B,S,H,hd); k/v (B,S,KV,hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    out = np.zeros_like(np.asarray(q, dtype=np.float32))
    qn, kn, vn = (np.asarray(t, dtype=np.float32) for t in (q, k, v))
    for bi in range(b):
        for hi in range(h):
            ki = hi // group
            logits = qn[bi, :, hi] @ kn[bi, :, ki].T / np.sqrt(hd)
            if causal:
                mask = np.tril(np.ones((s, s), bool))
                logits = np.where(mask, logits, -1e30)
            w = np.exp(logits - logits.max(-1, keepdims=True))
            w /= w.sum(-1, keepdims=True)
            out[bi, :, hi] = w @ vn[bi, :, ki]
    return out


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 2), (6, 3)])
def test_sdpa_matches_naive_gqa(h, kv):
    cfg = get_arch("qwen3-1.7b", smoke=True).replace(
        compute_dtype="float32", n_heads=h, n_kv_heads=kv
    )
    rng = np.random.default_rng(0)
    b, s, hd = 2, 16, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    got = layers._sdpa(cfg, q, k, v, causal=True)
    want = _naive_gqa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)


def test_chunked_sdpa_matches_naive():
    cfg = get_arch("qwen3-1.7b", smoke=True).replace(
        compute_dtype="float32", n_heads=4, n_kv_heads=2
    )
    rng = np.random.default_rng(1)
    b, s, hd = 1, 32, 8
    q = jnp.asarray(rng.normal(size=(b, s, 4, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, hd)), jnp.float32)
    got = layers._sdpa_chunked(cfg, q, k, v, True, chunk=8)
    want = _naive_gqa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)


def test_rope_preserves_norm_and_relative_position():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)
    y = layers.apply_rope(x, pos, theta=10_000.0)
    # rotation preserves per-head norms
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R_m q, R_n k> depends only on (m - n)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)

    def dot_at(m, n):
        qm = layers.apply_rope(q, jnp.array([m]), 10_000.0)
        kn = layers.apply_rope(k, jnp.array([n]), 10_000.0)
        return float(jnp.sum(qm * kn))

    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)
    np.testing.assert_allclose(dot_at(10, 4), dot_at(16, 10), rtol=1e-4)
