"""The analytic cost model must agree with reality:
  * closed-form param count == actual init_params leaf count, all 10 archs;
  * analytic FLOPs == XLA cost_analysis on a scan-free (unrolled) module,
    within tolerance, for a small dense config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.analytic_cost import _param_count, cell_cost
from repro.configs.base import ShapeConfig, get_arch, list_archs
from repro.models import model as model_lib


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_matches_init(arch):
    cfg = get_arch(arch, smoke=True)
    params_shape = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.key(0))
    )
    actual = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(params_shape)
    )
    predicted = _param_count(cfg)
    assert actual == int(predicted), (arch, actual, predicted)


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_matches_init_full(arch):
    cfg = get_arch(arch)  # full config — eval_shape only, no allocation
    params_shape = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.key(0))
    )
    actual = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(params_shape)
    )
    predicted = _param_count(cfg)
    assert actual == int(predicted), (arch, actual, predicted)


def test_analytic_flops_close_to_hlo():
    """Forward-only FLOPs vs cost_analysis on a loop-free lowering."""
    cfg = get_arch("qwen3-1.7b", smoke=True)
    shape = ShapeConfig("t", "prefill", 128, 4)

    params_shape = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.key(0))
    )

    def fwd_unrolled(params, tokens):
        # manual unroll (no scan): same math as forward for dense archs
        from repro.models import layers

        x = layers.embed(cfg, params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])
        for g in range(model_lib.n_groups(cfg)):
            lp = jax.tree.map(lambda t: t[g], params["groups"][0])
            h = layers.apply_norm(cfg, lp["ln1"], x)
            a, _ = layers.attention(cfg, lp["attn"], h, positions=positions)
            x = x + a
            h2 = layers.apply_norm(cfg, lp["ln2"], x)
            x = x + layers.apply_mlp(cfg, lp["mlp"], h2)
        x = layers.apply_norm(cfg, params["ln_f"], x)
        return layers.lm_logits(cfg, params["head"], params["embed"], x)

    low = jax.jit(fwd_unrolled).lower(
        params_shape, jax.ShapeDtypeStruct((4, 128), jnp.int32)
    )
    hlo_flops = float(low.cost_analysis().get("flops", 0.0))
    est = cell_cost(cfg, shape, n_model=1, n_batch_shards=1)
    # exclude bwd/opt (prefill kind = fwd only); tolerance: norms, softmax,
    # rope are not in the analytic model.
    assert hlo_flops > 0
    ratio = est["flops_global"] / hlo_flops
    assert 0.7 < ratio < 1.3, (est["flops_global"], hlo_flops)
