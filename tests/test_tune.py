"""Kernel autotuning & dispatch subsystem: registry constraints, cache
round trip, offline determinism, and the tuned-vs-heuristic bit-parity
contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.tune import autotune, cache, registry
from repro.obs import compile_log, metrics as obs_metrics, trace as obs_trace

RNG = np.random.default_rng(7)


def _make(m, d):
    x = RNG.laplace(size=(m, d)).astype(np.float32)
    xs = ops.standardize(jnp.asarray(x))
    return jnp.asarray(x), xs, ops.correlation(xs)


def _tmp_table(tmp_path):
    return cache.TuneTable(
        overlay_path_=str(tmp_path / "overlay.json")
    )


# ---------------------------------------------------------------------------
# Registry / dispatch
# ---------------------------------------------------------------------------


def test_heuristic_matches_legacy_pick_blocks():
    """The collapsed heuristic reproduces the old ops._pick_blocks table
    (duplicate d>=8/else branches folded — both returned 8)."""
    legacy = {
        (300, 4): (8, 8, 256),
        (300, 16): (8, 8, 256),
        (600, 64): (8, 8, 512),
        (600, 128): (8, 128, 512),
        (5000, 200): (8, 128, 2048),
    }
    for (m, d), want in legacy.items():
        assert registry.heuristic_pair_blocks(d, m) == want, (m, d)
        plan = registry.dispatch(
            "pairwise_moments", (m, d), backend="pallas", mode="off"
        )
        assert (plan.bi, plan.bj, plan.bm) == want


def test_dispatch_unknown_op_and_mode():
    with pytest.raises(ValueError, match="no kernel variant"):
        registry.dispatch("nope", (64, 8))
    with pytest.raises(ValueError, match="unknown tune mode"):
        registry.dispatch("pairwise_moments", (64, 8), mode="bogus")


def test_dispatch_mesh_compatibility():
    """The pair-tile kernel is local-only; the row-tile variant is the
    shard_map-safe one."""
    with pytest.raises(ValueError, match="not mesh-compatible"):
        registry.dispatch(
            "pairwise_moments", (64, 8), backend="pallas", mesh=True
        )
    plan = registry.dispatch(
        "pairwise_moment_sums_rows", (8, 8, 64), backend="pallas", mesh=True
    )
    assert plan.variant == "pallas-row-tile"


def test_candidates_respect_constraints():
    for op, shape, chunk in [
        ("pairwise_moments", (4096, 256), None),
        ("pairwise_moment_sums_rows", (64, 128, 2048), 512),
    ]:
        var = registry.get_variant(op, "pallas")
        cands = autotune.candidate_plans(
            op, shape, backend="pallas", chunk=chunk
        )
        assert len(cands) > 1
        for p in cands[1:]:  # [0] is the heuristic, kept unconditionally
            assert p.bi % 8 == 0 and p.bj % 8 == 0
            assert p.bm % registry.ACCUM_CHUNK == 0
            assert registry.vmem_bytes(p.bi, p.bj, p.bm) <= (
                var.constraints.vmem_budget
            )
            if chunk:
                assert p.bm <= chunk


def test_default_interpret_tracks_backend():
    """interpret=None resolves from the detected backend: the Pallas
    interpreter only when no accelerator backs the process."""
    expect = jax.default_backend() == "cpu"
    assert registry.default_interpret() is expect
    assert registry.resolve_interpret(None) is expect
    assert registry.resolve_interpret(True) is True
    assert registry.resolve_interpret(False) is False


# ---------------------------------------------------------------------------
# Cache: round trip + offline mode
# ---------------------------------------------------------------------------


def test_cache_round_trip_identical_dispatch(tmp_path):
    """Autotune (tiny grid, interpret mode on CPU) -> overlay write ->
    fresh reload -> dispatch returns the identical plan."""
    table = _tmp_table(tmp_path)
    tuned = autotune.autotune_op(
        "pairwise_moments", (128, 8), backend="pallas",
        interpret=True, quick=True, repeats=1, table=table,
    )
    # reload from disk into a brand-new table
    table2 = cache.TuneTable(overlay_path_=table.overlay_path)
    plan = registry.dispatch(
        "pairwise_moments", (128, 8), backend="pallas", table=table2
    )
    assert plan == tuned.best
    assert plan.source == "tuned"
    # the persisted entry is versioned + bucketed
    payload = json.load(open(table.overlay_path))
    assert payload["version"] == cache.SCHEMA_VERSION
    (key,) = payload["entries"].keys()
    assert key == tuned.key
    assert key.startswith(f"v{cache.SCHEMA_VERSION}/")


def test_plan_keys_separate_backends(tmp_path):
    """Blocked and pallas tunings at the same (op, dtype, bucket) must
    not collide: both stay retrievable."""
    table = _tmp_table(tmp_path)
    tb = autotune.autotune_op(
        "pairwise_moments", (128, 8), backend="blocked",
        quick=True, repeats=1, table=table,
    )
    tp = autotune.autotune_op(
        "pairwise_moments", (128, 8), backend="pallas",
        interpret=True, quick=True, repeats=1, table=table,
    )
    assert tb.key != tp.key
    got_b = registry.dispatch(
        "pairwise_moments", (128, 8), backend="blocked", table=table
    )
    got_p = registry.dispatch(
        "pairwise_moments", (128, 8), backend="pallas", table=table
    )
    assert got_b == tb.best and got_b.backend == "blocked"
    assert got_p == tp.best and got_p.backend == "pallas"


def test_auto_mode_never_searches_inside_a_trace(tmp_path, monkeypatch):
    """tune="auto" inside a jit trace degrades to the heuristic (the
    timed search would absorb tracing overhead and persist distorted
    plans); the search belongs to eager dispatch points (warm-up)."""
    import jax

    from repro.core import api
    from repro.data.simulate import simulate_lingam

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "auto.json"))
    cache.reset_table()
    try:
        gt = simulate_lingam(m=70, d=5, seed=9)
        x = jnp.asarray(gt.data)
        # distinct shape bucket from every other test so the jit cache
        # cannot have a stale entry for this (shape, config) pair
        ref = api.fit_fn(x, api.FitConfig(backend="blocked", tune="off"))
        got = api.fit_fn(x, api.FitConfig(backend="blocked", tune="auto"))
        assert np.array_equal(np.asarray(ref.order), np.asarray(got.order))
        assert not os.path.exists(str(tmp_path / "auto.json"))
        assert jax.core.trace_state_clean()
    finally:
        cache.reset_table()


def test_recorded_invalid_plan_degrades_without_research(tmp_path):
    """An entry that fails validation for the dispatch shape falls back
    to the heuristic deterministically — auto mode must not re-run the
    search once any entry exists for the bucket."""
    table = _tmp_table(tmp_path)
    key = cache.plan_key(
        registry.device_kind(), "pairwise_moments", "pallas", "float32",
        cache.shape_bucket("pairwise_moments", (300, 20)),
    )
    # bm not a multiple of ACCUM_CHUNK -> validate() rejects it
    table.record(key, {
        "variant": "pallas-pair-tile", "backend": "pallas",
        "bi": 8, "bj": 8, "bm": 96, "block": 0,
    })
    calls = []
    orig = autotune.autotune_op

    def spy(*a, **k):
        calls.append(a)
        return orig(*a, **k)

    autotune.autotune_op = spy
    try:
        p1 = registry.dispatch(
            "pairwise_moments", (300, 20), backend="pallas", mode="auto",
            table=table,
        )
        p2 = registry.dispatch(
            "pairwise_moments", (300, 20), backend="pallas", mode="auto",
            table=table,
        )
    finally:
        autotune.autotune_op = orig
    assert not calls  # entry exists -> no search, even though invalid
    heur = registry.dispatch(
        "pairwise_moments", (300, 20), backend="pallas", mode="off"
    )
    assert p1 == p2 == heur


def test_shape_bucketing_shares_plans(tmp_path):
    """Shapes in the same power-of-two bucket hit the same entry."""
    table = _tmp_table(tmp_path)
    autotune.autotune_op(
        "pairwise_moments", (100, 7), backend="blocked",
        quick=True, repeats=1, table=table,
    )
    a = registry.dispatch(
        "pairwise_moments", (100, 7), backend="blocked", table=table
    )
    b = registry.dispatch(
        "pairwise_moments", (97, 5), backend="blocked", table=table
    )
    assert a == b and a.source == "tuned"


def test_offline_mode_is_heuristic_and_deterministic(tmp_path):
    table = _tmp_table(tmp_path)
    autotune.autotune_op(
        "pairwise_moments", (128, 8), backend="pallas",
        interpret=True, quick=True, repeats=1, table=table,
    )
    offline = cache.TuneTable(
        overlay_path_=table.overlay_path, offline=True
    )
    assert offline.lookup(cache.plan_key(
        registry.device_kind(), "pairwise_moments", "pallas", "float32",
        cache.shape_bucket("pairwise_moments", (128, 8)),
    )) is None
    p1 = registry.dispatch(
        "pairwise_moments", (128, 8), backend="pallas", table=offline
    )
    p2 = registry.dispatch(
        "pairwise_moments", (128, 8), backend="pallas", mode="off",
        table=table,
    )
    assert p1 == p2 and p1.source == "heuristic"
    with pytest.raises(RuntimeError, match="offline"):
        offline.record("k", {})


def test_env_overlay_path(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "env.json"))
    assert cache.overlay_path() == str(tmp_path / "env.json")


# ---------------------------------------------------------------------------
# Parity: tuned plans == heuristic plans, bit for bit
# ---------------------------------------------------------------------------


def test_pair_op_parity_bit_identical_across_plans():
    """Every candidate block shape (the grid the tuner searches) returns
    bit-identical moments: bi/bj only re-tile the pair space, and bm is
    accumulated in fixed ACCUM_CHUNK sub-sums."""
    _, xs, c = _make(700, 24)
    heur = registry.dispatch(
        "pairwise_moments", (700, 24), backend="pallas", mode="off"
    )
    ref1, ref2 = ops.pairwise_moments(
        xs, c, backend="pallas", interpret=True, plan=heur
    )
    cands = autotune.candidate_plans(
        "pairwise_moments", (700, 24), backend="pallas"
    )
    assert len(cands) > 3
    for p in cands:
        m1, m2 = ops.pairwise_moments(
            xs, c, backend="pallas", interpret=True, plan=p
        )
        assert np.array_equal(np.asarray(ref1), np.asarray(m1)), p
        assert np.array_equal(np.asarray(ref2), np.asarray(m2)), p


def test_blocked_parity_bit_identical_across_blocks():
    _, xs, c = _make(700, 24)
    heur = registry.dispatch(
        "pairwise_moments", (700, 24), backend="blocked", mode="off"
    )
    ref1, ref2 = ops.pairwise_moments(xs, c, backend="blocked", plan=heur)
    for p in autotune.candidate_plans(
        "pairwise_moments", (700, 24), backend="blocked"
    ):
        m1, m2 = ops.pairwise_moments(xs, c, backend="blocked", plan=p)
        assert np.array_equal(np.asarray(ref1), np.asarray(m1)), p
        assert np.array_equal(np.asarray(ref2), np.asarray(m2)), p


def test_rows_op_parity_bit_identical_across_plans():
    _, xs, c = _make(512, 16)
    heur = registry.dispatch(
        "pairwise_moment_sums_rows", (16, 16, 512), backend="pallas",
        mode="off", chunk=512,
    )
    r1, r2 = ops.pairwise_moment_sums_rows(
        xs, c, 0, 16, chunk=512, backend="pallas", interpret=True,
        plan=heur,
    )
    for p in autotune.candidate_plans(
        "pairwise_moment_sums_rows", (16, 16, 512), backend="pallas",
        chunk=512,
    ):
        s1, s2 = ops.pairwise_moment_sums_rows(
            xs, c, 0, 16, chunk=512, backend="pallas", interpret=True,
            plan=p,
        )
        assert np.array_equal(np.asarray(r1), np.asarray(s1)), p
        assert np.array_equal(np.asarray(r2), np.asarray(s2)), p


def test_fit_results_identical_with_tuned_table(tmp_path):
    """End-to-end: a fit dispatched through a tuned table returns the
    same FitResult leaves as the offline heuristic fit."""
    from repro.core import api
    from repro.data.simulate import simulate_lingam

    table = _tmp_table(tmp_path)
    autotune.autotune_op(
        "pairwise_moments", (250, 9), backend="blocked",
        quick=True, repeats=1, table=table,
    )
    gt = simulate_lingam(m=250, d=9, seed=3)
    x = jnp.asarray(gt.data)
    ref = api.fit_fn(x, api.FitConfig(backend="blocked", tune="off"))
    # route the singleton table through the process cache
    os.environ["REPRO_TUNE_CACHE"] = table.overlay_path
    cache.reset_table()
    try:
        got = api.fit_fn(x, api.FitConfig(backend="blocked", tune="cache"))
    finally:
        del os.environ["REPRO_TUNE_CACHE"]
        cache.reset_table()
    assert np.array_equal(np.asarray(ref.order), np.asarray(got.order))
    assert np.array_equal(
        np.asarray(ref.adjacency), np.asarray(got.adjacency)
    )
    assert np.array_equal(
        np.asarray(ref.resid_var), np.asarray(got.resid_var)
    )


# ---------------------------------------------------------------------------
# Config plumbing + engine warm-up
# ---------------------------------------------------------------------------


def test_fitconfig_tune_validation():
    from repro.core import api

    api.FitConfig(tune="off")
    api.FitConfig(tune="auto")
    with pytest.raises(ValueError, match="tune"):
        api.FitConfig(tune="always")


def test_engine_warmup_resolves_plans_and_compiles(tmp_path, monkeypatch):
    from repro.core import api
    from repro.serve.engine import CausalDiscoveryEngine, FitRequest

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "warm.json"))
    cache.reset_table()
    try:
        eng = CausalDiscoveryEngine(
            api.FitConfig(backend="blocked", compaction="staged",
                          min_stage=3, tune="cache")
        )
        n0 = compile_log.total("batched.fit_many")
        plans = eng.warmup([(64, 5)])
        assert plans and all(
            isinstance(p, registry.Plan) for p in plans.values()
        )
        # Warmup pre-compiled the vmap fit program (public compile-log
        # pin: one event per (shape, config) signature).
        n_warm = compile_log.total("batched.fit_many")
        assert n_warm == n0 + 1
        x = RNG.laplace(size=(64, 5)).astype(np.float32)
        (req,) = eng.run([FitRequest(data=x)])
        assert sorted(req.result.order.tolist()) == list(range(5))
        # Steady state: the warmed shape serves with zero new compiles.
        assert compile_log.total("batched.fit_many") == n_warm
    finally:
        cache.reset_table()


def test_dispatch_telemetry_counts_variants(tmp_path, monkeypatch):
    """Enabled telemetry counts each dispatch by (op, variant, source)
    and never changes the resolved plan."""
    from repro import obs

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "t.json"))
    cache.reset_table()
    try:
        plain = registry.dispatch(
            "pairwise_moments", (512, 16), backend="blocked", mode="off"
        )
        obs.enable()
        obs_metrics.reset()
        try:
            traced = registry.dispatch(
                "pairwise_moments", (512, 16), backend="blocked",
                mode="off",
            )
            snap = obs_metrics.snapshot()["counters"]
        finally:
            obs.disable()
            obs_metrics.reset()
            obs_trace.reset()
        assert traced == plain
        (key,) = [k for k in snap if k.startswith("kernels.dispatch")]
        assert f'variant="{plain.variant}"' in key
        assert 'source="heuristic"' in key
        assert snap[key] == 1.0
    finally:
        cache.reset_table()


def test_rolling_window_moment_chunk_defaults_to_stream_chunk():
    """With an empty table the dispatcher-chosen moment_chunk degrades
    to the stream chunk exactly (the legacy default)."""
    from repro.stream.window import RollingVarLiNGAM

    r = RollingVarLiNGAM(d=4, chunk=64, window_chunks=3)
    assert r.config.moment_chunk == 64
