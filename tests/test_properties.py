"""Hypothesis property-based tests on system invariants.

``hypothesis`` is an *optional* dev dependency (see README / pyproject);
the whole module is skipped when it is not installed so tier-1 collection
stays green on minimal environments.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import measures
from repro.core.ordering import ordering_scores
from repro.kernels import ops

_SETTINGS = dict(max_examples=20, deadline=None, derandomize=True)


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(50, 400),
    d=st.integers(2, 12),
)
@settings(**_SETTINGS)
def test_standardize_moments(seed, m, d):
    rng = np.random.default_rng(seed)
    x = rng.laplace(size=(m, d)).astype(np.float32) * rng.uniform(0.5, 5.0, d)
    xs = np.asarray(ops.standardize(jnp.asarray(x)))
    np.testing.assert_allclose(xs.mean(axis=0), 0.0, atol=1e-4)
    np.testing.assert_allclose(xs.std(axis=0), 1.0, atol=1e-3)


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(100, 500))
@settings(**_SETTINGS)
def test_entropy_upper_bounded_by_gaussian(seed, m):
    """The max-entropy approximation is H_gauss minus non-negative terms."""
    rng = np.random.default_rng(seed)
    u = rng.laplace(size=m)
    u = (u - u.mean()) / u.std()
    h = float(measures.entropy(jnp.asarray(u, dtype=jnp.float32)))
    h_gauss = 0.5 * (1.0 + np.log(2 * np.pi))
    assert h <= h_gauss + 1e-6


@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 10.0),
    shift=st.floats(-5.0, 5.0),
)
@settings(**_SETTINGS)
def test_scores_affine_invariant(seed, scale, shift):
    """k_list scores are invariant to positive affine rescaling of columns
    (standardization removes location/scale)."""
    rng = np.random.default_rng(seed)
    x = rng.laplace(size=(300, 6)).astype(np.float32)
    active = jnp.ones(6, dtype=bool)
    k1, _, _ = ordering_scores(jnp.asarray(x), active)
    k2, _, _ = ordering_scores(jnp.asarray(x * scale + shift), active)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), atol=5e-3)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_correlation_properties(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((200, 8)).astype(np.float32)
    xs = ops.standardize(jnp.asarray(x))
    c = np.asarray(ops.correlation(xs))
    np.testing.assert_allclose(c, c.T, atol=1e-5)
    np.testing.assert_allclose(np.diag(c), 1.0, atol=1e-4)
    assert np.all(np.abs(c) <= 1.0 + 1e-4)


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(64, 300), d=st.integers(2, 10))
@settings(**_SETTINGS)
def test_pairwise_moments_sample_permutation_invariant(seed, m, d):
    """Moments are means over samples -> invariant to sample shuffling."""
    rng = np.random.default_rng(seed)
    x = rng.laplace(size=(m, d)).astype(np.float32)
    perm = rng.permutation(m)
    xs1 = ops.standardize(jnp.asarray(x))
    xs2 = ops.standardize(jnp.asarray(x[perm]))
    c1, c2 = ops.correlation(xs1), ops.correlation(xs2)
    m1a, m2a = ops.pairwise_moments(xs1, c1, backend="blocked")
    m1b, m2b = ops.pairwise_moments(xs2, c2, backend="blocked")
    mask = 1.0 - jnp.eye(d)
    np.testing.assert_allclose(
        np.asarray(m1a * mask), np.asarray(m1b * mask), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(m2a * mask), np.asarray(m2b * mask), atol=1e-5
    )
