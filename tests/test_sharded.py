"""Sharded (shard_map) causal ordering == single-device ordering.

Runs in a subprocess with XLA_FLAGS forcing 8 host devices so the main
test process keeps seeing exactly 1 device (per the dry-run contract).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.ordering import causal_order
    from repro.core.sharded import sharded_causal_order
    from repro.data.simulate import simulate_lingam

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for seed in (0, 1):
        gt = simulate_lingam(m=2000, d=9, seed=seed)
        ref = np.asarray(causal_order(jnp.asarray(gt.data)))
        with mesh:
            got = np.asarray(
                sharded_causal_order(gt.data, mesh, chunk=256)
            )
        assert np.array_equal(ref, got), (seed, ref, got)
    # pod-style 3-axis mesh
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    gt = simulate_lingam(m=1600, d=7, seed=3)
    ref = np.asarray(causal_order(jnp.asarray(gt.data)))
    with mesh3:
        got = np.asarray(
            sharded_causal_order(
                gt.data, mesh3, sample_axes=("pod", "data"), chunk=200
            )
        )
    assert np.array_equal(ref, got), (ref, got)
    print("SHARDED_OK")
    """
)


@pytest.mark.slow
def test_sharded_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout


_PALLAS_SCRIPT = _SCRIPT.replace(
    "sharded_causal_order(gt.data, mesh, chunk=256)",
    "sharded_causal_order(gt.data, mesh, chunk=256, backend='pallas')",
).replace(
    'sample_axes=("pod", "data"), chunk=200',
    'sample_axes=("pod", "data"), chunk=200, backend="pallas"',
)


@pytest.mark.slow
def test_sharded_pallas_backend_matches():
    """The Pallas kernel composed with shard_map == single-device order."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PALLAS_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout


_FUSED_SCRIPT = _SCRIPT.replace(
    "sharded_causal_order(gt.data, mesh, chunk=256)",
    "sharded_causal_order(gt.data, mesh, chunk=256, fused_standardize=True)",
).replace(
    'sample_axes=("pod", "data"), chunk=200',
    'sample_axes=("pod", "data"), chunk=200, fused_standardize=True',
)


@pytest.mark.slow
def test_sharded_fused_standardize_matches():
    """§Perf C2: raw-matmul + affine-fold correlation == reference order."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _FUSED_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout
