"""Collective helpers + mesh-elastic checkpoint restore (subprocess with 8
host devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import compressed_psum, hierarchical_psum

    # --- collective helpers: hierarchical == flat psum -------------------
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    x = np.arange(8 * 16, dtype=np.float32).reshape(8, 16) / 7.0

    def flat(v):
        return jax.lax.psum(v, ("pod", "data"))

    def hier(v):
        return hierarchical_psum(v, "pod", "data")

    def comp(v):
        return compressed_psum(v, ("pod", "data"))

    specs = dict(mesh=mesh, in_specs=P(("pod", "data"), None),
                 out_specs=P(("pod", "data"), None), check_rep=False)
    a = jax.jit(shard_map(flat, **specs))(x)
    b = jax.jit(shard_map(hier, **specs))(x)
    c = jax.jit(shard_map(comp, **specs))(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-2)
    print("COLLECTIVES_OK", flush=True)

    # --- mesh-elastic restore -------------------------------------------
    import tempfile
    from repro.configs.base import ShapeConfig, get_arch
    from repro.dist import sharding as shd
    from repro.train import checkpoint as ckpt
    from repro.train.optimizer import AdamW
    from repro.train.train_step import init_state

    cfg = get_arch("qwen3-1.7b", smoke=True)
    opt = AdamW()
    state = init_state(cfg, opt, jax.random.key(0))
    d = tempfile.mkdtemp()
    ckpt.save(d, 3, state, extra={"seed": 0, "step": 3})

    # restore onto a (4, 2) mesh with sharded params
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    p_sh = shd.param_shardings(cfg, state.params, mesh_a)
    sharded_params = jax.tree.map(
        lambda leaf, sh: jax.device_put(leaf, sh), state.params, p_sh
    )
    from repro.train.train_step import TrainState
    tmpl = TrainState(params=sharded_params, opt=state.opt)
    restored_a, _ = ckpt.restore(d, 3, tmpl)

    # restore the SAME checkpoint onto a different (2, 4) mesh
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    p_sh_b = shd.param_shardings(cfg, state.params, mesh_b)
    sharded_b = jax.tree.map(
        lambda leaf, sh: jax.device_put(leaf, sh), state.params, p_sh_b
    )
    restored_b, _ = ckpt.restore(d, 3, TrainState(params=sharded_b,
                                                  opt=state.opt))
    for x1, x2 in zip(jax.tree.leaves(restored_a.params),
                      jax.tree.leaves(restored_b.params)):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    print("ELASTIC_OK", flush=True)
    """
)


@pytest.mark.slow
def test_collectives_and_elastic_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "COLLECTIVES_OK" in out.stdout
    assert "ELASTIC_OK" in out.stdout
