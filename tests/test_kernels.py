"""Pallas pairwise-stats kernel vs the pure-jnp oracle: shape/dtype sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.pairwise_stats import pairwise_moments_pallas

RNG = np.random.default_rng(42)


def _make(m, d, dtype=np.float32, dist="laplace"):
    if dist == "laplace":
        x = RNG.laplace(size=(m, d))
    else:
        x = RNG.uniform(size=(m, d))
    x = x.astype(dtype)
    xs = ops.standardize(jnp.asarray(x, dtype=jnp.float32))
    c = ops.correlation(xs)
    return xs, c


def _offdiag_close(a, b, d, atol):
    mask = 1.0 - jnp.eye(d)
    np.testing.assert_allclose(
        np.asarray(a * mask), np.asarray(b * mask), atol=atol, rtol=0
    )


@pytest.mark.parametrize(
    "m,d",
    [(64, 4), (100, 5), (257, 10), (511, 16), (1000, 33), (2048, 64), (4096, 130)],
)
def test_pallas_matches_oracle_shapes(m, d):
    xs, c = _make(m, d)
    m1r, m2r = ref.pairwise_moments_ref(xs, c)
    m1p, m2p = ops.pairwise_moments(xs, c, backend="pallas", interpret=True)
    _offdiag_close(m1r, m1p, d, atol=2e-6)
    _offdiag_close(m2r, m2p, d, atol=2e-6)


@pytest.mark.parametrize("m,d", [(300, 7), (1024, 24)])
def test_blocked_matches_oracle(m, d):
    xs, c = _make(m, d)
    m1r, m2r = ref.pairwise_moments_ref(xs, c)
    m1b, m2b = ops.pairwise_moments(xs, c, backend="blocked")
    _offdiag_close(m1r, m1b, d, atol=2e-6)
    _offdiag_close(m2r, m2b, d, atol=2e-6)


@pytest.mark.parametrize("bi,bj,bm", [(8, 8, 256), (8, 128, 512), (16, 16, 256)])
def test_pallas_block_shape_sweep(bi, bj, bm):
    m, d = 777, 40
    xs, c = _make(m, d)
    m1r, m2r = ref.pairwise_moments_ref(xs, c)
    d_pad = ((d + max(bi, bj) - 1) // max(bi, bj)) * max(bi, bj)
    m_pad = ((m + bm - 1) // bm) * bm
    xt = jnp.pad(xs.T, ((0, d_pad - d), (0, m_pad - m)))
    cp = jnp.pad(c, ((0, d_pad - d), (0, d_pad - d)))
    m1p, m2p = pairwise_moments_pallas(
        xt, cp, m_total=m, bi=bi, bj=bj, bm=bm, interpret=True
    )
    _offdiag_close(m1r, m1p[:d, :d], d, atol=2e-6)
    _offdiag_close(m2r, m2p[:d, :d], d, atol=2e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("dist", ["laplace", "uniform"])
def test_pallas_dtype_dist_sweep(dtype, dist):
    m, d = 500, 12
    xs, c = _make(m, d, dtype=dtype, dist=dist)
    m1r, m2r = ref.pairwise_moments_ref(xs, c)
    m1p, m2p = ops.pairwise_moments(xs, c, backend="pallas", interpret=True)
    _offdiag_close(m1r, m1p, d, atol=2e-6)
    _offdiag_close(m2r, m2p, d, atol=2e-6)


def test_bf16_input_upcast():
    m, d = 512, 16
    x = RNG.laplace(size=(m, d)).astype(np.float32)
    xs32 = ops.standardize(jnp.asarray(x))
    c32 = ops.correlation(xs32)
    xs16 = xs32.astype(jnp.bfloat16)
    m1r, _ = ref.pairwise_moments_ref(xs32, c32)
    m1p, _ = ops.pairwise_moments(
        xs16.astype(jnp.float32), c32, backend="pallas", interpret=True
    )
    # bf16 data has ~3 decimal digits; moments agree loosely.
    _offdiag_close(m1r, m1p, d, atol=1e-2)


# Padding edges: tile / d / m just above and below the block multiples
# (bi=8, bj=8, bm=128/256), pinned against the blocked-oracle sums. The
# ops wrappers pad to the plan's blocks and mask/slice the excess; these
# cells would silently corrupt the edge rows/columns if the padding or
# the m_total mask were off by one.
_EDGE_CELLS = [
    # (tile, d, m): d and m straddle block multiples; tile straddles bi.
    (7, 9, 127),    # all just below/above the 8/128 quanta
    (8, 16, 129),   # m one past a bm sub-chunk
    (9, 15, 255),   # tile just above bi, m just below 2*128
    (8, 17, 257),   # d one past 2*8, m one past 2*128
    (16, 16, 128),  # exact multiples (no-padding control cell)
]


def _rows_oracle_sums(xs, c, tile):
    """Blocked-oracle row sums: means * m, first `tile` rows."""
    m = xs.shape[0]
    m1r, m2r = ref.pairwise_moments_ref(xs, c)
    return np.asarray(m1r)[:tile] * m, np.asarray(m2r)[:tile] * m


@pytest.mark.parametrize("tile,d,m", _EDGE_CELLS)
def test_rows_padding_edges_vs_blocked_oracle(tile, d, m):
    from repro.kernels.tune import Plan

    xs, c = _make(m, d)
    s1r, s2r = _rows_oracle_sums(xs, c, tile)
    # force a plan whose blocks do NOT divide the shape, so the wrapper
    # must pad every axis and mask the sample tail
    plan = Plan(
        op="pairwise_moment_sums_rows", variant="pallas-row-tile",
        backend="pallas", bi=8, bj=8, bm=128, source="override",
    )
    s1, s2 = ops.pairwise_moment_sums_rows(
        xs, c, 0, tile, backend="pallas", interpret=True, plan=plan
    )
    assert s1.shape == (tile, d)
    mask = 1.0 - np.eye(tile, d)
    np.testing.assert_allclose(
        np.asarray(s1) * mask, s1r * mask, atol=2e-6 * m, rtol=0
    )
    np.testing.assert_allclose(
        np.asarray(s2) * mask, s2r * mask, atol=2e-6 * m, rtol=0
    )
    # the blocked backend is exact at the same cells (chunk > m forces
    # a single padded slab)
    b1, b2 = ops.pairwise_moment_sums_rows(
        xs, c, 0, tile, chunk=64, backend="blocked"
    )
    np.testing.assert_allclose(
        np.asarray(b1) * mask, s1r * mask, atol=2e-6 * m, rtol=0
    )


@pytest.mark.parametrize("tile,d,m", _EDGE_CELLS)
def test_fused_padding_edges_vs_blocked_oracle(tile, d, m):
    from repro.kernels.tune import Plan

    x = RNG.laplace(size=(m, d)).astype(np.float32)
    xj = jnp.asarray(x)
    xs = ops.standardize(xj)
    c = ops.correlation(xs)
    s1r, s2r = _rows_oracle_sums(xs, c, tile)
    mu = jnp.mean(xj, axis=0)
    rstd = 1.0 / jnp.maximum(jnp.std(xj, axis=0), 1e-12)
    plan = Plan(
        op="fused_moment_sums", variant="pallas-fused",
        backend="pallas", bi=8, bj=8, bm=256, source="override",
    )
    s1, s2 = ops.fused_moment_rows(
        xj, mu, rstd, c, 0, tile, interpret=True, plan=plan
    )
    assert s1.shape == (tile, d)
    mask = 1.0 - np.eye(tile, d)
    np.testing.assert_allclose(
        np.asarray(s1) * mask, s1r * mask, atol=4e-6 * m, rtol=0
    )
    np.testing.assert_allclose(
        np.asarray(s2) * mask, s2r * mask, atol=4e-6 * m, rtol=0
    )


def test_chunked_padding_edge_vs_oracle():
    """Chunk-accumulated sums at a non-divisible window length."""
    m, d = 333, 10
    xs, c = _make(m, d)
    m1r, m2r = ref.pairwise_moments_ref(xs, c)
    for backend in ("blocked", "pallas"):
        m1, m2 = ops.pairwise_moments_chunked(
            xs, c, chunk=128, backend=backend, interpret=True
        )
        _offdiag_close(m1r, m1, d, atol=2e-6)
        _offdiag_close(m2r, m2, d, atol=2e-6)


@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_fused_kernel_matches_oracle(dtype):
    """Fused standardize+moments kernel (raw X in, optional bf16 streaming)
    vs the standardize-then-oracle pipeline (§Perf C2+C3)."""
    from repro.kernels.fused_stats import fused_moment_sums

    m, d, tile = 512, 16, 8
    x = RNG.laplace(size=(m, d)).astype(np.float32)
    xs = ops.standardize(jnp.asarray(x))
    c = ops.correlation(xs)
    m1r, m2r = ref.pairwise_moments_ref(xs, c)

    mu = jnp.mean(jnp.asarray(x), axis=0)
    sd = jnp.maximum(jnp.std(jnp.asarray(x), axis=0), 1e-12)
    rstd = 1.0 / sd
    xr = jnp.asarray(x).T  # (d, m) raw
    if dtype == "bfloat16":
        xr = xr.astype(jnp.bfloat16)
    s1, s2 = fused_moment_sums(
        xr[:tile], xr, mu[:tile], mu, rstd[:tile], rstd, c[:tile],
        m_total=m, bi=8, bj=8, bm=256, interpret=True,
    )
    atol = 2e-6 if dtype == np.float32 else 5e-2
    # mask the degenerate self-pair entries (i, i) of the (tile, d) slab
    mask = 1.0 - jnp.eye(tile, d)
    np.testing.assert_allclose(
        np.asarray(m1r[:tile] * m * mask), np.asarray(s1 * mask),
        atol=atol * m, rtol=0,
    )
    np.testing.assert_allclose(
        np.asarray(m2r[:tile] * m * mask), np.asarray(s2 * mask),
        atol=atol * m, rtol=0,
    )
