"""MoE and Mamba2 layer invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import mamba2, moe
from repro.models.moe import apply_moe, init_moe, n_experts_padded


def _moe_cfg(**kw):
    return get_arch("qwen2-moe-a2.7b", smoke=True).replace(
        compute_dtype="float32", **kw
    )


def test_expert_padding_counts():
    assert n_experts_padded(get_arch("qwen2-moe-a2.7b")) == 64  # 60 -> 64
    assert n_experts_padded(get_arch("olmoe-1b-7b")) == 64      # already 64
    assert n_experts_padded(get_arch("jamba-v0.1-52b")) == 16   # unchanged
    smoke = get_arch("qwen2-moe-a2.7b", smoke=True)
    assert n_experts_padded(smoke) == smoke.n_experts  # tiny: no padding


def test_padded_experts_never_selected():
    cfg = get_arch("qwen2-moe-a2.7b", smoke=True).replace(n_experts=6)
    # force padding by pretending 17 experts -> pads to 32
    cfg17 = cfg.replace(n_experts=17, n_experts_active=2)
    p = init_moe(cfg17, jax.random.key(0))
    assert p["router"].shape[1] == 32
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg17.d_model))
    probs, gates, idx = moe._route(cfg17, p, x.reshape(1, 32, -1))
    assert int(jnp.max(idx)) < 17  # padded experts (17..31) never routed


def test_scatter_matches_einsum_dispatch():
    cfg = _moe_cfg(capacity_factor=8.0)  # high capacity: no token drops
    p = init_moe(cfg, jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model)) * 0.5
    y_s, aux_s = apply_moe(cfg, p, x, impl="scatter", group_size=16)
    y_e, aux_e = apply_moe(cfg, p, x, impl="einsum", group_size=16)
    np.testing.assert_allclose(
        np.asarray(y_s), np.asarray(y_e), atol=1e-4
    )
    np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-5)


def test_capacity_drops_tokens_not_crash():
    cfg = _moe_cfg(capacity_factor=0.25)  # aggressive dropping
    p = init_moe(cfg, jax.random.key(4))
    x = jax.random.normal(jax.random.key(5), (2, 32, cfg.d_model))
    y, aux = apply_moe(cfg, p, x, impl="scatter", group_size=32)
    assert np.isfinite(np.asarray(y)).all()
    # shared experts still serve dropped tokens -> output nonzero
    assert float(jnp.mean(jnp.abs(y))) > 0


def test_mamba_chunk_size_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    cfg = get_arch("mamba2-2.7b", smoke=True).replace(compute_dtype="float32")
    p = mamba2.init_mamba(cfg, jax.random.key(6))
    x = jax.random.normal(jax.random.key(7), (2, 64, cfg.d_model)) * 0.5
    outs = []
    for q in (8, 16, 32, 64):
        cfg_q = cfg.replace(ssm_chunk=q)
        y, _ = mamba2.apply_mamba(cfg_q, p, x)
        outs.append(np.asarray(y))
    for y in outs[1:]:
        np.testing.assert_allclose(outs[0], y, atol=2e-4)


def test_mamba_prefill_state_continues_sequence():
    """prefill(x[:t]) state + decode steps == full forward outputs."""
    cfg = get_arch("mamba2-2.7b", smoke=True).replace(compute_dtype="float32")
    p = mamba2.init_mamba(cfg, jax.random.key(8))
    x = jax.random.normal(jax.random.key(9), (1, 12, cfg.d_model)) * 0.5
    y_full, _ = mamba2.apply_mamba(cfg, p, x)
    y_pre, cache = mamba2.apply_mamba(cfg, p, x[:, :8], return_cache=True)
    np.testing.assert_allclose(
        np.asarray(y_full[:, :8]), np.asarray(y_pre), atol=2e-4
    )
    ys = []
    for t in range(8, 12):
        y_t, cache = mamba2.apply_mamba_decode(cfg, p, x[:, t : t + 1], cache)
        ys.append(np.asarray(y_t))
    got = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), got, atol=2e-3)
