"""Performance-accounting layer: cost capture, roofline math, pins.

Covers the profiling PR's contracts:

  * disabled profiling is a plain passthrough: `FitResult`s are
    bit-identical to the enabled run, warm programs never recompile,
    and no cost records appear — the same zero-delta pin spans carry.
  * cost records are keyed with the exact `compile_log` scheme, so the
    captured signatures across fit / bootstrap / query paths are a
    subset of the compile-event keys (the join contract).
  * captured records carry XLA `cost_analysis` FLOPs/bytes and
    `memory_analysis` watermarks and accumulate call statistics.
  * the analytic pairwise-moments cost model matches the hand-computed
    FLOP/byte oracle, and `utilization`/`roofline_terms` reproduce the
    roofline arithmetic exactly.
  * the device-peaks registry resolves by device-kind substring and
    honors the `REPRO_PEAKS` calibration override.
  * the HLO collective-bytes parser and the stage-attribution report
    machinery (`analysis.report`) keep their schemas.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import api, batched
from repro.infer import query as query_lib
from repro.obs import compile_log, profile

_CFG = api.FitConfig(backend="blocked", compaction="staged")


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.disable()
    profile.disable()
    obs.reset_all()
    yield
    obs.disable()
    profile.disable()
    obs.reset_all()


def _data(m=192, d=6, seed=0):
    rng = np.random.default_rng(seed)
    w = np.triu(rng.uniform(0.3, 0.8, (d, d)), 1) * (rng.random((d, d)) < 0.5)
    e = rng.laplace(size=(m, d)).astype(np.float32)
    return np.linalg.solve(np.eye(d) - w.T, e.T).T.astype(np.float32)


def _leaves(res):
    return [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(res)]


# ---------------------------------------------------------------------------
# disabled-path pin: bit-identical results, zero compile delta, no records
# ---------------------------------------------------------------------------


def test_disabled_profiling_is_bit_identical_and_recordless():
    x = jnp.asarray(_data())
    base = api.fit_fn(x, _CFG)

    profile.enable()
    on = api.fit_fn(x, _CFG)
    assert profile.records(), "enabled profiling captured nothing"

    profile.disable()
    profile.reset()
    off = api.fit_fn(x, _CFG)
    assert profile.records() == [], "disabled profiling left records"

    for a, b, c in zip(_leaves(base), _leaves(on), _leaves(off)):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_disabled_profiling_adds_no_compiles_on_warm_programs():
    x = jnp.asarray(_data())
    api.fit_fn(x, _CFG)  # warm the program
    compile_log.reset()

    for _ in range(3):
        api.fit_fn(x, _CFG)  # warm + disabled: no retrace, no capture
    assert compile_log.total() == 0
    assert profile.records() == []


def test_call_passthrough_forwards_args_and_result():
    profile.disable()
    out = profile.call(lambda a, b=0: a + b, 2, b=3, op="noop")
    assert out == 5
    assert profile.get("noop") is None


# ---------------------------------------------------------------------------
# key-join contract: profile keys are a subset of compile_log keys
# ---------------------------------------------------------------------------


def _compile_keys():
    return {(e["op"], tuple(e["shape"]), e["config"])
            for e in compile_log.events()}


def _profile_keys():
    return {(r.op, tuple(r.shape), r.config) for r in profile.records()}


def test_cost_keys_join_compile_log_across_fit_bootstrap_query():
    profile.enable()
    x = _data(m=160, d=5)
    xj = jnp.asarray(x)

    res = api.fit_fn(xj, _CFG)

    rng = np.random.default_rng(0)
    idx = jnp.asarray(
        rng.integers(0, x.shape[0], size=(3, x.shape[0])), dtype=jnp.int32
    )
    batched.bootstrap_fits(xj, idx, _CFG)

    eng = query_lib.QueryEngine()
    eng.run([query_lib.EffectQuery(graph=res),
             query_lib.EffectQuery(graph=res)])

    prof = _profile_keys()
    assert prof, "no cost records captured"
    ops = {k[0] for k in prof}
    assert "core.fit" in ops
    assert "batched.bootstrap_fits" in ops
    assert "query.effects" in ops
    missing = prof - _compile_keys()
    assert not missing, f"cost keys with no compile event: {missing}"
    assert np.asarray(res.order).shape == (5,)


def test_capture_records_cost_and_memory_watermarks():
    profile.enable()
    x = jnp.asarray(_data(m=256, d=8))
    api.fit_fn(x, _CFG)
    api.fit_fn(x, _CFG)

    rec = profile.get("core.fit", x.shape, _CFG)
    assert rec is not None
    assert rec.source == "measured"
    assert rec.flops > 0 and rec.bytes_accessed > 0
    assert rec.arg_bytes >= x.size * 4  # at least the input slab
    assert rec.calls == 2
    assert 0 < rec.best_s <= rec.total_s

    row = rec.row(profile.DevicePeaks("t", 1e12, 1e11, 1e10))
    assert row["op"] == "core.fit" and row["calls"] == 2
    assert row["gflops_per_s"] > 0 and row["bound"] in ("compute", "memory")
    json.dumps(row)  # JSON-safe

    snap = profile.snapshot()
    assert snap["device"]["name"]
    assert any(r["op"] == "core.fit" for r in snap["records"])


# ---------------------------------------------------------------------------
# roofline math vs the hand-computed pairwise_moments oracle
# ---------------------------------------------------------------------------


def test_analytic_cost_matches_hand_oracle():
    m, d = 256, 8
    # 35 flops per (pair, sample): residual, log cosh, u*exp(-u^2/2),
    # two accumulates — times d*d pairs times m samples.
    want_flops = 35 * d * d * m
    # fp32 streamed traffic: x and its standardized copy read (2*m*d),
    # both (d, d) moment outputs written.
    want_bytes = 4 * (2 * m * d + 2 * d * d)

    got = profile.analytic_cost("pairwise_moments", (m, d))
    assert got["flops"] == pytest.approx(want_flops)
    assert got["bytes"] == pytest.approx(want_bytes)
    assert got["intensity"] == pytest.approx(want_flops / want_bytes)

    tile = 4
    got_rows = profile.analytic_cost("pairwise_moment_sums_rows",
                                     (tile, d, m))
    assert got_rows["flops"] == pytest.approx(35 * tile * d * m)
    assert got_rows["bytes"] == pytest.approx(
        4 * (m * tile + m * d + 2 * tile * d))

    assert profile.analytic_cost("unknown_op", (m, d)) is None
    assert profile.analytic_cost("pairwise_moments", None) is None


def test_utilization_reproduces_roofline_arithmetic():
    peaks = profile.DevicePeaks("toy", flops_per_s=100e9, hbm_bw=20e9,
                                ici_bw=10e9)
    flops, nbytes, secs = 35 * 8 * 8 * 256, 4 * (2 * 256 * 8 + 2 * 64), 1e-3
    u = profile.utilization(flops, nbytes, secs, peaks)

    assert u["gflops_per_s"] == pytest.approx(flops / secs / 1e9)
    assert u["gbytes_per_s"] == pytest.approx(nbytes / secs / 1e9)
    t_compute, t_memory = flops / 100e9, nbytes / 20e9
    assert u["roofline_frac"] == pytest.approx(
        max(t_compute, t_memory) / secs)
    assert u["bound"] == ("compute" if t_compute >= t_memory else "memory")
    assert u["peaks"] == "toy"

    # compute-bound corner: huge flops, tiny traffic
    u2 = profile.utilization(1e12, 1.0, 1.0, peaks)
    assert u2["bound"] == "compute"
    assert u2["roofline_frac"] == pytest.approx(10.0)  # 1e12/100e9 per 1s


def test_roofline_terms_wrapper_agrees():
    from repro.analysis import roofline

    peaks = profile.DevicePeaks("toy", 100e9, 20e9, 10e9)
    t = roofline.roofline_terms(1e9, 1e9, 5e8, peaks=peaks)
    assert t["compute_s"] == pytest.approx(1e9 / 100e9)
    assert t["memory_s"] == pytest.approx(1e9 / 20e9)
    assert t["collective_s"] == pytest.approx(5e8 / 10e9)
    assert t["dominant"] == "memory"
    assert t["bound_s"] == pytest.approx(max(1e9 / 100e9, 1e9 / 20e9))


# ---------------------------------------------------------------------------
# device-peaks registry
# ---------------------------------------------------------------------------


def test_device_peaks_resolution_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_PEAKS", raising=False)
    assert profile.device_peaks("NVIDIA H100 80GB HBM3").name == "gpu-h100"
    assert profile.device_peaks("TPU v4").name == "tpu-v4"
    assert profile.device_peaks("cpu").name == "cpu-generic"
    assert profile.device_peaks("weird accelerator").name == "unknown"
    # the process's own device resolves to *something* in the table
    assert profile.device_peaks().flops_per_s > 0

    monkeypatch.setenv("REPRO_PEAKS", "flops=3.2e12,hbm=80e9,name=calibrated")
    p = profile.device_peaks("cpu")
    assert p.name == "calibrated"
    assert p.flops_per_s == pytest.approx(3.2e12)
    assert p.hbm_bw == pytest.approx(80e9)
    assert p.ici_bw == pytest.approx(10e9)  # untouched field survives


# ---------------------------------------------------------------------------
# HLO collective-bytes parser (the surviving piece of the LM scaffold)
# ---------------------------------------------------------------------------


def test_collective_bytes_parses_optimized_hlo():
    hlo = """
HloModule m
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[256,256]{1,0} all-gather(%ar), dimensions={0}
  ROOT %t = (f32[256,256]) tuple(%ag)
}
"""
    got = profile.collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 4
    assert got["all-gather"] == 128 * 256 * 4  # operand bytes, not result
    assert got["reduce-scatter"] == 0


# ---------------------------------------------------------------------------
# stage-attribution report
# ---------------------------------------------------------------------------


def test_live_attribution_rows_carry_schema():
    from repro.analysis import report

    payload = report.live_attribution(m=128, d=5, backend="blocked",
                                      repeats=1, include_pallas=False)
    stages = {r["stage"] for r in payload["rows"]}
    assert {"ordering", "pruning", "solve", "full_fit"} <= stages
    for row in payload["rows"]:
        for key in report.STAGE_KEYS:
            assert key in row, f"stage row missing {key}"
        assert row["best_s"] > 0
    assert payload["kernels"], "no kernel-variant rows"
    assert payload["kernels"][0]["backend"] == "blocked"
    text = report.render(payload)
    assert "per-stage attribution" in text and "full_fit" in text


def test_report_smoke_validates_committed_artifact():
    from repro.analysis import report

    assert report.smoke() == 0, "committed BENCH_profile.json failed smoke"
