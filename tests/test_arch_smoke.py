"""Per-architecture smoke tests: reduced config, one forward/train step and
one prefill+decode step on CPU; asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch, list_archs
from repro.models import model as model_lib

SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 32, 2)
SMOKE_PREFILL = ShapeConfig("smoke_prefill", "prefill", 32, 2)

ARCHS = list_archs()


def _batch(cfg, shape, seed=0):
    from repro.launch.input_specs import make_host_batch

    return make_host_batch(cfg, shape, seed=seed)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_arch(arch, smoke=True)
    params = model_lib.init_params(cfg, jax.random.key(0), max_seq=64)
    batch = _batch(cfg, SMOKE_TRAIN)
    logits, aux = jax.jit(
        lambda p, b: model_lib.forward(
            cfg, p, b["tokens"], frontend=b.get("frontend")
        )
    )(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    loss = jax.jit(lambda p, b: model_lib.lm_loss(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_no_nans(arch):
    cfg = get_arch(arch, smoke=True)
    params = model_lib.init_params(cfg, jax.random.key(1), max_seq=64)
    batch = _batch(cfg, SMOKE_TRAIN, seed=1)
    grads = jax.jit(
        jax.grad(lambda p: model_lib.lm_loss(cfg, p, batch))
    )(params)
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_arch(arch, smoke=True)
    params = model_lib.init_params(cfg, jax.random.key(2), max_seq=48)
    pre = _batch(cfg, SMOKE_PREFILL, seed=2)
    enc_out = None
    if cfg.family in ("audio", "vlm"):
        enc_out = pre["frontend"].astype(jnp.bfloat16)

    last, caches = jax.jit(
        lambda p, b: model_lib.prefill(
            cfg, p, b["tokens"], max_seq=48, frontend=b.get("frontend")
        )
    )(params, pre)
    assert last.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(last, np.float32)).all()

    token = jnp.argmax(last[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)[:, None]
    logits, caches = jax.jit(
        lambda p, t, c, pos: model_lib.decode_step(
            cfg, p, t, c, pos, enc_out=enc_out
        )
    )(params, token, caches, jnp.int32(32))
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_decode_consistency_with_forward():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_arch("qwen3-1.7b", smoke=True).replace(compute_dtype="float32")
    params = model_lib.init_params(cfg, jax.random.key(3), max_seq=16)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)

    full_logits, _ = model_lib.forward(cfg, params, tokens)
    last, caches = model_lib.prefill(cfg, params, tokens[:, :7], max_seq=16)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, 6, :]), atol=2e-3
    )
    # one decode step with the true 8th token
    logits, _ = model_lib.decode_step(
        cfg, params, tokens[:, 7:8], caches, jnp.int32(7)
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 7, :]), atol=2e-3
    )


def test_mamba_decode_consistency():
    cfg = get_arch("mamba2-2.7b", smoke=True).replace(compute_dtype="float32")
    params = model_lib.init_params(cfg, jax.random.key(4), max_seq=16)
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    full_logits, _ = model_lib.forward(cfg, params, tokens)
    last, caches = model_lib.prefill(cfg, params, tokens[:, :7], max_seq=16)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, 6, :]), atol=5e-3
    )
    logits, _ = model_lib.decode_step(
        cfg, params, tokens[:, 7:8], caches, jnp.int32(7)
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 7, :]), atol=5e-3
    )


def test_chunked_attention_matches_dense():
    """Flash-style chunked attention must equal dense attention."""
    cfg = get_arch("glm4-9b", smoke=True).replace(
        compute_dtype="float32", attn_impl="chunked", attn_chunk=8
    )
    cfg_d = cfg.replace(attn_impl="dense")
    params = model_lib.init_params(cfg, jax.random.key(7), max_seq=32)
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    lc, _ = model_lib.forward(cfg, params, tokens)
    ld, _ = model_lib.forward(cfg_d, params, tokens)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ld), atol=2e-3)
    # decode path too
    last_c, cache_c = model_lib.prefill(cfg, params, tokens[:, :16], max_seq=32)
    last_d, cache_d = model_lib.prefill(cfg_d, params, tokens[:, :16], max_seq=32)
    np.testing.assert_allclose(
        np.asarray(last_c), np.asarray(last_d), atol=2e-3
    )
