"""The examples must actually run (subprocess, reduced settings)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + ":" + ROOT
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable] + args,
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=timeout,
    )
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-2500:])
    return out.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "parallel == sequential: True" in out
    assert "orders agree : True" in out
