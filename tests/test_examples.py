"""The examples must actually run (subprocess, reduced settings)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + ":" + ROOT
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable] + args,
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=timeout,
    )
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-2500:])
    return out.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "parallel == sequential: True" in out
    assert "orders agree : True" in out


@pytest.mark.slow
def test_train_lm_smoke_and_serve(tmp_path):
    out = _run([
        "examples/train_lm.py", "--smoke",
        "--ckpt-dir", str(tmp_path / "ck"),
    ])
    assert "trained to step 20" in out
    assert "generated" in out


@pytest.mark.slow
def test_activation_causality():
    out = _run(["examples/activation_causality.py"])
    assert "layer causal order" in out


@pytest.mark.slow
def test_launch_train_smoke(tmp_path):
    out = _run([
        "-m", "repro.launch.train", "--arch", "qwen3-1.7b", "--smoke",
        "--steps", "5", "--ckpt-dir", str(tmp_path / "ck"),
    ])
    assert "done: step=5" in out


@pytest.mark.slow
def test_launch_serve_smoke():
    out = _run([
        "-m", "repro.launch.serve", "--arch", "qwen2-1.5b", "--smoke",
        "--requests", "2", "--batch", "2", "--new-tokens", "4",
        "--max-seq", "32", "--prompt-len", "8",
    ])
    assert "tok/s" in out
