"""Observability subsystem: spans, metrics, compile log, regression CLI.

Covers the telemetry PR's contracts:

  * spans nest by host call stack, carry attributes, record errors,
    and render as a tree; disabled telemetry returns a shared no-op.
  * metrics survive concurrent serving sessions (exact counter totals
    under a thread storm) and export snapshot / Prometheus text.
  * jit-safety: instrumented and uninstrumented fits are bit-identical
    with equal compile counts, and enabling telemetry triggers no
    retrace of warm programs.
  * enabled-telemetry overhead stays under 2% of a bootstrap-style
    batched fit (primitive cost bound, not a flaky wall-clock A/B).
  * the compile log is queryable by op / signature and powers the
    public one-compile-per-bucket pins.
  * ``analysis/regress.py`` flags out-of-tolerance slowdowns (nonzero
    exit), respects the tolerance band and absolute floor, and its
    ``--smoke`` mode validates committed artifacts.
"""

import json
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.analysis import regress
from repro.core import api, batched
from repro.data.simulate import simulate_lingam
from repro.obs import compile_log, metrics, trace


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.disable()
    obs.reset_all()
    yield
    obs.disable()
    obs.reset_all()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_spans_nest_and_carry_attrs():
    obs.enable()
    with obs.span("outer", d=4) as outer:
        with obs.span("inner", step=1):
            pass
        with obs.span("inner", step=2) as s:
            s.set(variant="blocked")
    (root,) = obs.roots()
    assert root is outer
    assert root.attrs == {"d": 4}
    assert [c.name for c in root.children] == ["inner", "inner"]
    assert root.children[1].attrs == {"step": 2, "variant": "blocked"}
    assert root.duration_s >= max(c.duration_s for c in root.children)
    tree = obs.format_tree()
    assert "outer" in tree and "{step=2, variant=blocked}" in tree


def test_span_records_error_and_unwinds_stack():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("nope")
    (root,) = obs.roots()
    assert root.attrs["error"] == "ValueError"
    with obs.span("after"):
        pass
    assert [r.name for r in obs.roots()] == ["boom", "after"]  # not nested


def test_disabled_telemetry_is_noop():
    assert not obs.enabled()
    s = obs.span("x", d=1)
    assert s is obs.span("y")  # the shared no-op singleton
    with s:
        metrics.inc("c")
        metrics.observe("h", 1.0)
        metrics.gauge("g", 2.0)
    assert obs.roots() == []
    snap = metrics.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
    assert obs.format_tree() == "(no spans recorded)"


def test_spans_feed_latency_histograms():
    obs.enable()
    with obs.span("stage"):
        pass
    h = metrics.snapshot()["histograms"]["span.stage_s"]
    assert h["count"] == 1 and h["max"] >= 0.0


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metric_series_keyed_by_labels():
    obs.enable()
    metrics.inc("q", 2, kind="effects")
    metrics.inc("q", 3, kind="rca")
    metrics.inc("q", kind="effects")
    metrics.gauge("stale", 4, sid="s0")
    for v in (0.1, 0.2, 0.3, 0.4):
        metrics.observe("lat_s", v, d=8)
    snap = metrics.snapshot()
    assert snap["counters"]['q{kind="effects"}'] == 3.0
    assert snap["counters"]['q{kind="rca"}'] == 3.0
    assert snap["gauges"]['stale{sid="s0"}'] == 4.0
    h = snap["histograms"]['lat_s{d="8"}']
    assert h["count"] == 4 and h["max"] == 0.4
    assert abs(h["sum"] - 1.0) < 1e-12
    assert 0.1 <= h["p50"] <= h["p95"] <= h["p99"] <= 0.4


def test_metrics_stable_under_concurrent_sessions():
    """A thread storm of counter/histogram/span traffic loses nothing:
    counter totals are exact and snapshots taken mid-storm never see
    torn state."""
    obs.enable()
    n_threads, n_iter = 8, 300
    errs = []

    def session(tid):
        try:
            for i in range(n_iter):
                with obs.span("sess.step", tid=tid):
                    metrics.inc("sess.requests", sid=f"s{tid}")
                    metrics.observe("sess.lat_s", i * 1e-6)
                if i % 50 == 0:
                    snap = metrics.snapshot()
                    assert set(snap) == {"counters", "gauges", "histograms"}
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [
        threading.Thread(target=session, args=(t,)) for t in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    snap = metrics.snapshot()
    per_sid = [snap["counters"][f'sess.requests{{sid="s{t}"}}']
               for t in range(n_threads)]
    assert per_sid == [float(n_iter)] * n_threads
    assert snap["histograms"]["sess.lat_s"]["count"] == n_threads * n_iter
    # Every thread's roots landed (each thread has its own span stack).
    assert sum(r.name == "sess.step" for r in obs.roots()) == min(
        n_threads * n_iter, 256
    )


def test_prometheus_text_format():
    obs.enable()
    metrics.inc("serve.requests", 5, kind="fit")
    metrics.gauge("stream.staleness_chunks", 2, sid="s0")
    metrics.observe("serve.flush_s", 0.25)
    text = metrics.to_prometheus_text()
    assert 'serve_requests_total{kind="fit"} 5.0' in text
    assert 'stream_staleness_chunks{sid="s0"} 2.0' in text
    assert "serve_flush_s_count 1" in text
    assert "serve_flush_s_p99 0.25" in text
    assert text.endswith("\n")


def test_prometheus_help_type_headers_once_per_family():
    obs.enable()
    metrics.inc("serve.requests", 2, kind="fit")
    metrics.inc("serve.requests", 3, kind="flush")
    metrics.gauge("stream.cadence_chunks", 8, sid="s0")
    text = metrics.to_prometheus_text()
    assert text.count("# TYPE serve_requests_total counter") == 1
    assert text.count("# HELP serve_requests_total ") == 1
    assert text.count("# TYPE stream_cadence_chunks gauge") == 1
    lines = text.splitlines()
    # Headers precede their family's sample lines.
    t = lines.index("# TYPE serve_requests_total counter")
    assert lines[t + 1].startswith("serve_requests_total{")
    assert lines[t + 2].startswith("serve_requests_total{")


def test_prometheus_escapes_label_values():
    obs.enable()
    metrics.inc("serve.flush_errors", 1,
                error='shape ("x", 2)\nmismatch \\ bad')
    text = metrics.to_prometheus_text()
    assert (
        r'serve_flush_errors_total{error="shape (\"x\", 2)\n'
        r'mismatch \\ bad"} 1.0' in text
    )
    assert "\nmismatch" not in text  # no raw newline inside a sample


def test_chrome_trace_events():
    obs.enable()
    with obs.span("serve.flush", n_due=3):
        with obs.span("serve.flush_bucket", shape=(6, 6)):
            time.sleep(0.002)
    doc = obs.to_chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert set(by_name) == {"serve.flush", "serve.flush_bucket"}
    outer, inner = by_name["serve.flush"], by_name["serve.flush_bucket"]
    for e in (outer, inner):
        assert e["ph"] == "X"
        assert e["cat"] in ("host", "jax-trace")
        assert e["pid"] == 0 and e["tid"] == 0
    # Child nests inside the parent on the timeline, timestamps
    # rebased to the earliest root.
    assert outer["ts"] == 0.0
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0
    assert inner["args"] == {"shape": "(6, 6)"}  # attrs stringified


def test_write_chrome_trace_roundtrip(tmp_path):
    obs.enable()
    with obs.span("fit", d=4):
        pass
    path = obs.write_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["traceEvents"][0]["name"] == "fit"


# ---------------------------------------------------------------------------
# BoundedRing
# ---------------------------------------------------------------------------


def test_bounded_ring_caps_and_counts_drops():
    ring = obs.BoundedRing(3)
    for i in range(5):
        ring.append(i)
    assert list(ring) == [2, 3, 4]  # oldest evicted first
    assert len(ring) == 3
    assert ring.dropped == 2
    assert ring[0] == 2 and ring[-1] == 4
    assert ring[1:] == [3, 4]
    assert bool(ring)
    ring.clear()
    assert not ring and ring.dropped == 0


def test_bounded_ring_drain_empties_oldest_first():
    ring = obs.BoundedRing(8)
    ring.extend("abc")
    assert ring.drain() == ["a", "b", "c"]
    assert ring.drain() == []
    assert not ring


def test_bounded_ring_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        obs.BoundedRing(0)


# ---------------------------------------------------------------------------
# jit-safety: bit-identical results, equal compile counts, bounded cost
# ---------------------------------------------------------------------------

_CFG = api.FitConfig(backend="blocked", compaction="staged")


def test_instrumented_fit_bit_identical_and_no_retrace():
    gt = simulate_lingam(m=400, d=7, seed=42)
    x = jnp.asarray(gt.data)

    r_off = api.fit_fn(x, _CFG)
    n_off = compile_log.total()
    obs.enable()
    r_on = api.fit_fn(x, _CFG)  # warm program: no retrace under telemetry
    assert compile_log.total() == n_off
    np.testing.assert_array_equal(
        np.asarray(r_off.order), np.asarray(r_on.order)
    )
    np.testing.assert_array_equal(
        np.asarray(r_off.adjacency), np.asarray(r_on.adjacency)
    )
    np.testing.assert_array_equal(
        np.asarray(r_off.resid_var), np.asarray(r_on.resid_var)
    )


def test_instrumented_trace_compiles_and_matches_uninstrumented():
    """Fresh shapes traced with telemetry ON and OFF compile the same
    number of programs and agree bit-for-bit (spans/metrics stage no
    ops into the trace)."""
    gt = simulate_lingam(m=352, d=6, seed=7)

    obs.enable()
    n0 = compile_log.total()
    r_on = api.fit_fn(jnp.asarray(gt.data), _CFG)
    compiles_on = compile_log.total() - n0
    tree_on = obs.format_tree()
    assert compiles_on >= 1
    assert "[trace]" in tree_on  # stage spans ran at trace time

    obs.disable()
    obs.reset_all()
    gt2 = simulate_lingam(m=353, d=6, seed=7)  # new shape -> fresh trace
    n1 = compile_log.total()
    api.fit_fn(jnp.asarray(gt2.data), _CFG)
    compiles_off = compile_log.total() - n1
    assert compiles_off == compiles_on

    # Identical input through the telemetry-on-traced program vs the
    # telemetry-off-traced one: same compiled math, same bits.
    r_off = api.fit_fn(jnp.asarray(gt.data), _CFG)
    np.testing.assert_array_equal(
        np.asarray(r_on.adjacency), np.asarray(r_off.adjacency)
    )


def test_enabled_overhead_under_two_percent():
    """Bound enabled-telemetry cost against the bootstrap workload: one
    warm batched fit through the serving path issues < 25 span/metric
    primitives (serve.run + fit_bucket spans, two observes, a counter,
    their histogram feeds); 25 of them must cost under 2% of the fit.
    (The primitive-cost ratio is deterministic where a wall-clock A/B
    of two full runs would be CI noise.)"""
    gt = simulate_lingam(m=500, d=8, seed=3)
    idx = batched.resample_indices(0, 16, gt.data.shape[0])
    x = jnp.asarray(gt.data)
    batched.bootstrap_fits(x, idx, _CFG).order.block_until_ready()  # warm
    t_fit = min(
        _timed(lambda: batched.bootstrap_fits(x, idx, _CFG)
               .order.block_until_ready())
        for _ in range(3)
    )

    obs.enable()
    n = 1000
    t0 = time.perf_counter()
    for i in range(n):
        with obs.span("overhead.probe", i=i):
            metrics.inc("overhead.calls")
            metrics.observe("overhead.val_s", 1e-6)
    per_probe = (time.perf_counter() - t0) / n
    assert per_probe * 25 < 0.02 * t_fit, (
        f"telemetry primitive cost {per_probe * 1e6:.1f}us/probe too high "
        f"vs fit {t_fit * 1e3:.1f}ms"
    )


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# compile log
# ---------------------------------------------------------------------------


def test_compile_log_keys_and_queries():
    compile_log.record("op.a", shape=(64, 5), config=_CFG, note="first")
    compile_log.record("op.a", shape=(64, 5), config=_CFG)
    compile_log.record("op.a", shape=(128, 5), config=_CFG)
    compile_log.record("op.b")
    key = ("op.a", (64, 5), compile_log.config_hash(_CFG))
    assert compile_log.counts("op.a")[key] == 2
    assert compile_log.total("op.a") == 3
    assert compile_log.by_op() == {"op.a": 3, "op.b": 1}
    assert [e["op"] for e in compile_log.events("op.b")] == ["op.b"]
    assert compile_log.events("op.a")[0]["note"] == "first"
    snap = compile_log.snapshot()
    assert snap["by_op"]["op.a"] == 3
    assert any(k.startswith("op.a:[64, 5]") for k in snap["by_signature"])
    # Distinct configs hash to distinct signatures.
    other = api.FitConfig(backend="blocked", prune_method="adaptive")
    assert compile_log.config_hash(other) != compile_log.config_hash(_CFG)
    assert compile_log.config_hash(None) == "-"


def test_compile_log_always_on_and_feeds_metrics_when_enabled():
    assert not obs.enabled()
    compile_log.record("op.silent", shape=(2,))
    assert compile_log.total("op.silent") == 1  # recorded while disabled
    assert metrics.snapshot()["counters"] == {}
    obs.enable()
    compile_log.record("op.loud")
    assert metrics.snapshot()["counters"]['compiles{op="op.loud"}'] == 1.0


# ---------------------------------------------------------------------------
# regression tracker
# ---------------------------------------------------------------------------


def _fake_artifact(scale=1.0):
    return {
        "bench": "bootstrap",
        "quick": True,
        "timestamp": "2026-01-01T00:00:00",
        "rows": [{
            "cell": "m2000.d16", "m": 2000, "d": 16,
            "loop_s": 1.0 * scale, "vmap_s": 0.1 * scale,
            "vmap_fits_per_s": 100.0 / scale, "speedup": 10.0,
            "edge_prob_agree": 0.99,  # not a perf metric
        }],
    }


def test_collect_metrics_directions_and_labels():
    got = regress.collect_metrics(_fake_artifact())
    assert got["rows[cell=m2000.d16,m=2000,d=16].loop_s"] == ("lower", 1.0)
    assert got["rows[cell=m2000.d16,m=2000,d=16].vmap_fits_per_s"] == (
        "higher", 100.0
    )
    assert not any(m.endswith("edge_prob_agree") for m in got)
    # Time units normalize to seconds (ms/us suffixes).
    us = regress.collect_metrics({"rows": [{"op": "k", "tuned": {"us": 2.0}}]})
    assert us["rows[op=k].tuned.us"] == ("lower", 2e-6)


def test_compare_tolerance_band_and_floor():
    base = regress.collect_metrics(_fake_artifact(1.0))
    # 50% slower: beyond tol and the absolute floor -> regression.
    worse = {d.metric: d for d in regress.compare(
        base, regress.collect_metrics(_fake_artifact(1.5)),
        tol=0.25, min_abs=0.005,
    )}
    assert worse["rows[cell=m2000.d16,m=2000,d=16].loop_s"].status == \
        "REGRESSED"
    assert worse["rows[cell=m2000.d16,m=2000,d=16].vmap_fits_per_s"].status \
        == "REGRESSED"  # rate fell below the band
    # 10% slower: inside the band -> ok.
    ok = regress.compare(
        base, regress.collect_metrics(_fake_artifact(1.1)),
        tol=0.25, min_abs=0.005,
    )
    assert all(d.status == "ok" for d in ok)
    # Microsecond-scale jitter: relatively huge, absolutely tiny -> the
    # floor keeps it from failing a build.
    tiny_b = {"m.t_s": ("lower", 1e-4)}
    tiny_c = {"m.t_s": ("lower", 3e-4)}
    (d,) = regress.compare(tiny_b, tiny_c, tol=0.25, min_abs=0.005)
    assert d.status == "ok"
    (d,) = regress.compare(tiny_b, tiny_c, tol=0.25, min_abs=0.0)
    assert d.status == "REGRESSED"


def test_compare_flags_new_and_missing_metrics():
    base = {"a_s": ("lower", 1.0)}
    cur = {"b_s": ("lower", 1.0)}
    by = {d.metric: d.status for d in regress.compare(
        base, cur, tol=0.25, min_abs=0.005
    )}
    assert by == {"a_s": "missing", "b_s": "new"}


def test_regress_cli_exit_codes(tmp_path, capsys):
    basedir, curdir = tmp_path / "base", tmp_path / "cur"
    basedir.mkdir(), curdir.mkdir()
    (basedir / "BENCH_bootstrap.json").write_text(
        json.dumps(_fake_artifact(1.0))
    )
    (curdir / "BENCH_bootstrap.json").write_text(
        json.dumps(_fake_artifact(2.0))
    )
    rc = regress.main([
        "--baseline-dir", str(basedir), "--current-dir", str(curdir),
        "--only", "bootstrap",
    ])
    assert rc == 1
    assert "REGRESSED" in capsys.readouterr().out
    # Same artifacts within tolerance -> success.
    (curdir / "BENCH_bootstrap.json").write_text(
        json.dumps(_fake_artifact(1.05))
    )
    assert regress.main([
        "--baseline-dir", str(basedir), "--current-dir", str(curdir),
        "--only", "bootstrap",
    ]) == 0
    # Smoke mode self-compares the baselines.
    assert regress.main([
        "--baseline-dir", str(basedir), "--smoke", "--only", "bootstrap",
    ]) == 0
    # No baselines at all is an error.
    assert regress.main(["--baseline-dir", str(curdir / "nope")]) == 2


def test_regress_smoke_on_committed_artifacts():
    """The repo's own BENCH_*.json artifacts parse and yield metrics."""
    rc = regress.main(["--smoke"])
    assert rc == 0


def test_provenance_shape():
    prov = obs.provenance(repo_root=str(regress._REPO_ROOT))
    for k in ("timestamp", "jax_version", "device_kind", "git_sha"):
        assert k in prov
    assert prov["git_sha"] not in ("", None)
