"""Causal query & effect-inference subsystem.

Covers the inference PR's contracts:

  * ``total_effects`` (triangular solve in causal order) matches the
    dense ``(I - B)^{-1}`` oracle to 1e-5 and is jit/vmap-clean (the
    vmapped batch equals the per-item loop bit-for-bit).
  * analytic total effects match the brute-force Monte-Carlo
    do-sampling oracle (``simulate_do`` with common random numbers).
  * path-specific effects decompose (through = total - avoiding) and
    lag-propagated VAR impulse responses match the numpy recursion.
  * interventional means/covariances from observational moments match
    interventional sampling — including moments pulled from a
    streaming ``MomentState`` (no row re-reads).
  * RCA recovers an injected anomalous noise variable, and the
    contribution split sums exactly to the target's deviation.
  * bootstrap effect CIs cover the true effect, with the resample fits
    identical to the plain ``bootstrap_fits`` engine.
  * the query engine answers a mixed-shape micro-batch with one
    compile per (kind, shape) bucket (pinned through the public
    ``repro.obs.compile_log``) and results identical to the direct
    single-query path; stream-session ids resolve through the serving
    engine.
  * hypothesis property: relabeling variables permutes the effect
    matrix accordingly (effects are invariant to variable order).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, batched
from repro.data.simulate import simulate_do, simulate_lingam
from repro.infer import effects, intervene, query, rca
from repro.obs import compile_log
from repro.serve.engine import CausalDiscoveryEngine
from repro.stream import StreamConfig, stats

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - minimal envs
    HAVE_HYPOTHESIS = False

_CFG = api.FitConfig(backend="blocked", compaction="staged")


def _fit(gt):
    return api.fit_fn(jnp.asarray(gt.data), _CFG)


def _true_result(gt) -> api.FitResult:
    """A FitResult carrying the ground-truth graph (uniform(0,1) noise:
    mean 1/2, variance 1/12)."""
    d = gt.adjacency.shape[0]
    return api.FitResult(
        order=jnp.asarray(gt.order, jnp.int32),
        adjacency=jnp.asarray(gt.adjacency, jnp.float32),
        resid_var=jnp.full((d,), 1.0 / 12.0, jnp.float32),
    )


def _dense_oracle(adjacency) -> np.ndarray:
    b = np.asarray(adjacency, np.float64)
    return np.linalg.inv(np.eye(b.shape[0]) - b)


# ---------------------------------------------------------------------------
# total effects
# ---------------------------------------------------------------------------


def test_total_effects_matches_dense_inverse():
    gt = simulate_lingam(m=3000, d=12, seed=3)
    res = _fit(gt)
    t = np.asarray(effects.total_effects(res))
    np.testing.assert_allclose(
        t, _dense_oracle(res.adjacency), atol=1e-5
    )
    assert np.allclose(np.diagonal(t), 1.0)


def test_total_effects_vmap_equals_loop():
    xs = jnp.stack([
        jnp.asarray(simulate_lingam(m=1500, d=7, seed=s).data)
        for s in range(3)
    ])
    fits = batched.fit_many(xs, _CFG)
    many = jax.jit(jax.vmap(effects.total_effects_impl))(
        fits.adjacency, fits.order
    )
    for i in range(3):
        one = effects.total_effects_impl(
            fits.adjacency[i], fits.order[i]
        )
        np.testing.assert_array_equal(np.asarray(many[i]), np.asarray(one))


def test_total_effects_matches_monte_carlo_do_oracle():
    gt = simulate_lingam(m=100, d=8, seed=1)
    t_true = np.asarray(
        effects.total_effects(_true_result(gt))
    )
    # Common random numbers: the finite difference of do-sample means is
    # the effect column exactly, not just in expectation.
    for j in (int(gt.order[0]), int(gt.order[3])):
        lo = simulate_do(gt.adjacency, {j: 0.5}, m=2000, seed=7)
        hi = simulate_do(gt.adjacency, {j: 1.5}, m=2000, seed=7)
        mc_col = (hi - lo).mean(axis=0)
        np.testing.assert_allclose(t_true[:, j], mc_col, atol=1e-4)

    # Same oracle against an *estimated* graph (nontrivial causal order,
    # dense fitted coefficients): sample from the fitted SEM itself.
    res = _fit(gt)
    b_hat = np.asarray(res.adjacency)
    t_hat = np.asarray(effects.total_effects(res))
    j = int(res.order[0])
    lo = simulate_do(b_hat, {j: 0.0}, m=2000, seed=3)
    hi = simulate_do(b_hat, {j: 1.0}, m=2000, seed=3)
    np.testing.assert_allclose(
        t_hat[:, j], (hi - lo).mean(axis=0), atol=1e-4
    )


def test_simulate_do_pins_target():
    gt = simulate_lingam(m=10, d=6, seed=0)
    x = simulate_do(gt.adjacency, {2: 3.25}, m=500, seed=0)
    assert np.all(x[:, 2] == np.float32(3.25))


def test_path_specific_effects_decompose():
    # Chain 0 -> 1 -> 2 plus the direct edge 0 -> 2.
    b = np.zeros((3, 3), np.float32)
    b[1, 0], b[2, 1], b[2, 0] = 0.5, 0.8, 0.3
    order = jnp.arange(3, dtype=jnp.int32)
    blocked = jnp.asarray([False, True, False])
    avoiding = np.asarray(
        effects.effects_avoiding(jnp.asarray(b), order, blocked)
    )
    through = np.asarray(
        effects.effects_through(jnp.asarray(b), order, blocked)
    )
    assert avoiding[2, 0] == pytest.approx(0.3)
    assert through[2, 0] == pytest.approx(0.5 * 0.8)
    total = np.asarray(effects.total_effects_impl(jnp.asarray(b), order))
    assert total[2, 0] == pytest.approx(0.3 + 0.5 * 0.8)


def test_var_irf_matches_numpy_recursion():
    rng = np.random.default_rng(0)
    d, k, horizon = 5, 2, 6
    b0 = np.tril(rng.normal(size=(d, d)) * 0.4, k=-1).astype(np.float32)
    mats = (rng.normal(size=(k, d, d)) * 0.15).astype(np.float32)
    irf = np.asarray(effects.var_irf(
        b0, jnp.arange(d, dtype=jnp.int32), mats, horizon
    ))
    a0 = np.linalg.inv(np.eye(d) - b0)
    phis = [np.eye(d)]
    for h in range(1, horizon + 1):
        phi = sum(
            mats[tau - 1] @ phis[h - tau]
            for tau in range(1, min(h, k) + 1)
        )
        phis.append(phi)
    for h in range(horizon + 1):
        np.testing.assert_allclose(irf[h], phis[h] @ a0, atol=1e-4)


# ---------------------------------------------------------------------------
# interventions
# ---------------------------------------------------------------------------


def test_interventional_moments_match_do_sampling():
    gt = simulate_lingam(m=100, d=8, seed=2)
    res = _true_result(gt)
    t = _dense_oracle(gt.adjacency)
    obs_mean = t @ np.full(8, 0.5)
    obs_cov = t @ (np.eye(8) / 12.0) @ t.T
    j = int(gt.order[1])
    mu, cov = intervene.interventional_moments(
        res, {j: 2.0}, mean=obs_mean, cov=obs_cov
    )
    x_do = simulate_do(gt.adjacency, {j: 2.0}, m=60_000, seed=5)
    np.testing.assert_allclose(mu, x_do.mean(axis=0), atol=0.02)
    np.testing.assert_allclose(
        cov, np.cov(x_do.T, ddof=0), atol=0.05
    )
    assert mu[j] == pytest.approx(2.0, abs=1e-5)
    assert abs(cov[j, j]) < 1e-6  # pinned: zero variance


def test_interventional_from_moment_state():
    gt = simulate_lingam(m=40_000, d=6, seed=4)
    res = _fit(gt)
    state = stats.from_chunk(jnp.asarray(gt.data))
    j = int(res.order[0])
    mu_state, cov_state = intervene.interventional_from_state(
        res, state, {j: 1.0}
    )
    mu_direct, cov_direct = intervene.interventional_moments(
        res, {j: 1.0},
        mean=gt.data.mean(axis=0), cov=np.cov(gt.data.T, ddof=0),
    )
    np.testing.assert_allclose(mu_state, mu_direct, atol=1e-4)
    np.testing.assert_allclose(cov_state, cov_direct, atol=1e-4)
    # And both agree with interventional sampling from the true graph.
    x_do = simulate_do(gt.adjacency, {j: 1.0}, m=60_000, seed=9)
    np.testing.assert_allclose(mu_state, x_do.mean(axis=0), atol=0.05)


# ---------------------------------------------------------------------------
# root-cause attribution
# ---------------------------------------------------------------------------


def _anomalous_rows(gt, k: int, shift: float, n: int, seed: int):
    """Rows whose variable-k noise term is shifted by ``shift``."""
    d = gt.adjacency.shape[0]
    rng = np.random.default_rng(seed)
    e = rng.uniform(0.0, 1.0, size=(n, d))
    e[:, k] += shift
    return np.linalg.solve(
        np.eye(d) - gt.adjacency, e.T
    ).T.astype(np.float32)


def test_rca_recovers_injected_anomalous_noise():
    gt = simulate_lingam(m=20_000, d=8, seed=6)
    res = _fit(gt)
    t_true = _dense_oracle(gt.adjacency)
    k = int(gt.order[0])  # a causal root: anomalies propagate widely
    downstream = np.abs(t_true[:, k]) * (np.arange(8) != k)
    target = int(np.argmax(downstream))
    assert downstream[target] > 0.1  # seed sanity: k reaches target

    rows = _anomalous_rows(gt, k, shift=6.0, n=32, seed=11)
    report = rca.attribute(
        res, rows, mean=gt.data.mean(axis=0), target=target
    )
    # The implicated root is the injected variable for every sample.
    assert np.all(report.root == k)
    # |z| of the injected noise is extreme; others are ordinary.
    assert np.abs(report.scores[:, k]).min() > 5.0
    # The additive split is exact: contributions sum to the target's
    # deviation from the observational mean.
    np.testing.assert_allclose(
        report.contributions.sum(axis=1),
        rows[:, target] - gt.data.mean(axis=0)[target],
        atol=1e-3,
    )
    # ... and the injected root dominates the split.
    top = np.argmax(np.abs(report.contributions), axis=1)
    assert np.all(top == k)


def test_rca_chunked_slabs_match_whole_batch():
    gt = simulate_lingam(m=4000, d=6, seed=8)
    res = _fit(gt)
    rows = gt.data[:301]
    whole = rca.attribute(res, rows, mean=gt.data.mean(axis=0))
    slabbed = rca.attribute(
        res, rows, mean=gt.data.mean(axis=0), chunk=64
    )
    np.testing.assert_array_equal(whole.scores, slabbed.scores)
    np.testing.assert_array_equal(whole.root, slabbed.root)


# ---------------------------------------------------------------------------
# bootstrap effect CIs
# ---------------------------------------------------------------------------


def test_bootstrap_effect_ci_covers_true_effect():
    gt = simulate_lingam(m=2500, d=6, seed=12)
    t_true = _dense_oracle(gt.adjacency)
    ci = effects.bootstrap_effects(
        gt.data, n_sampling=30, level=0.9, seed=0, config=_CFG
    )
    off = ~np.eye(6, dtype=bool)
    strongest = np.unravel_index(
        np.argmax(np.abs(t_true) * off), t_true.shape
    )
    assert ci.covers(t_true)[strongest]
    # Overall coverage is high (deterministic under the seed).
    assert ci.covers(t_true)[off].mean() >= 0.8
    i, j = strongest
    assert any(
        (si, sj) == (int(i), int(j))
        for si, sj, *_ in ci.significant_effects()
    )


def test_bootstrap_fits_with_matches_plain_bootstrap():
    gt = simulate_lingam(m=800, d=6, seed=13)
    idx = batched.resample_indices(3, 8, gt.data.shape[0])
    plain = batched.bootstrap_fits(jnp.asarray(gt.data), idx, _CFG)
    fits, effs = batched.bootstrap_fits_with(
        jnp.asarray(gt.data), idx, _CFG, effects._effects_post
    )
    np.testing.assert_array_equal(
        np.asarray(plain.adjacency), np.asarray(fits.adjacency)
    )
    for s in range(8):
        np.testing.assert_allclose(
            np.asarray(effs[s]),
            _dense_oracle(np.asarray(plain.adjacency[s])),
            atol=1e-5,
        )


# ---------------------------------------------------------------------------
# query engine
# ---------------------------------------------------------------------------


def test_query_engine_one_compile_per_bucket():
    # Unique dims so earlier tests' jit caches cannot mask compiles.
    fits = {
        d: _fit(simulate_lingam(m=1200, d=d, seed=d)) for d in (9, 13)
    }
    means = {d: np.zeros((d,), np.float32) for d in (9, 13)}
    engine = query.QueryEngine(batch_size=8)

    def make_queries():
        return [
            query.EffectQuery(graph=fits[9]),
            query.EffectQuery(graph=fits[9]),
            query.EffectQuery(graph=fits[13]),
            query.InterventionQuery(graph=fits[9], do={0: 1.0}),
            query.InterventionQuery(graph=fits[9], do={3: -1.0, 1: 0.5}),
            query.RCAQuery(
                graph=fits[9], rows=np.ones((7, 9), np.float32), target=2
            ),
        ]

    before = {op: compile_log.total(op) for op in
              ("query.effects", "query.intervention", "query.rca")}
    qs = engine.run(make_queries())
    # One compile per (kind, shape) bucket: effects d=9 (pair) and d=13
    # (singleton) are distinct buckets; interventions share one; RCA one.
    assert compile_log.total("query.effects") - before["query.effects"] == 2
    assert (compile_log.total("query.intervention")
            - before["query.intervention"]) == 1
    assert compile_log.total("query.rca") - before["query.rca"] == 1
    after = compile_log.total()

    # Steady state: the identical mix re-executes with zero compiles.
    qs2 = engine.run(make_queries())
    assert compile_log.total() == after

    # Answers match the direct single-query paths.
    for q in (qs[0], qs[1], qs[2]):
        np.testing.assert_allclose(
            q.effects,
            np.asarray(effects.total_effects(q.graph.result)),
            atol=1e-6,
        )
    mu, cov = intervene.interventional_moments(
        qs[3].graph.result, {0: 1.0}, mean=means[9]
    )
    np.testing.assert_allclose(qs[3].mean, mu, atol=1e-6)
    np.testing.assert_allclose(qs[3].cov, cov, atol=1e-6)
    direct = rca.attribute(
        fits[9], np.ones((7, 9), np.float32), mean=means[9], target=2
    )
    np.testing.assert_allclose(
        qs[5].result.scores, direct.scores, atol=1e-6
    )
    np.testing.assert_allclose(
        qs[5].result.contributions, direct.contributions, atol=1e-6
    )
    assert qs2[0].effects is not None


def test_engine_queries_resolve_stream_sessions():
    d, chunk, window_chunks = 6, 64, 3
    engine = CausalDiscoveryEngine(_CFG, batch_size=2)
    cfg = StreamConfig(
        d=d, chunk=chunk, window_chunks=window_chunks, fit=_CFG
    )
    sid = engine.open_stream(cfg)
    gt = simulate_lingam(m=chunk * (window_chunks + 2), d=d, seed=14)
    deltas = []
    for k in range(window_chunks + 2):
        deltas += engine.post_chunk(
            sid, gt.data[k * chunk:(k + 1) * chunk]
        )
    if not deltas:
        deltas = engine.flush_streams()
    assert deltas, "stream session never produced an estimate"

    session = engine.stream_session(sid)
    qs = engine.query([
        query.EffectQuery(graph=sid),
        query.InterventionQuery(graph=sid, do={1: 2.0}),
        query.RCAQuery(graph=sid, rows=gt.data[:5]),
    ])
    np.testing.assert_allclose(
        qs[0].effects,
        np.asarray(effects.total_effects(session.last_fit.result)),
        atol=1e-6,
    )
    assert qs[1].mean is not None and qs[1].mean[1] == pytest.approx(2.0)
    assert qs[2].result.scores.shape == (5, d)
    # The session graph's observational mean came from the moment store,
    # not a data pass — it matches the window mean.
    win_mean = np.asarray(session.rolling.aug_state.mean)[:d]
    np.testing.assert_allclose(qs[0].graph.mean, win_mean, atol=1e-6)

    # Re-issuing the *same* query objects after the session refits must
    # answer from the live estimate, not the first call's snapshot.
    old_effects = qs[0].effects.copy()
    gt2 = simulate_lingam(m=chunk * 2, d=d, seed=15)
    for k in range(2):
        engine.post_chunk(sid, gt2.data[k * chunk:(k + 1) * chunk])
    engine.flush_streams()
    engine.query(qs)
    fresh = np.asarray(
        effects.total_effects(session.last_fit.result)
    )
    np.testing.assert_allclose(qs[0].effects, fresh, atol=1e-6)
    assert not np.allclose(qs[0].effects, old_effects)


def test_query_engine_rejects_unresolved_string_ref():
    with pytest.raises(TypeError):
        query.QueryEngine().run([query.EffectQuery(graph="stream-0")])


# ---------------------------------------------------------------------------
# property: effects are equivariant under variable relabeling
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        d=st.integers(2, 7),
    )
    def test_effects_invariant_under_relabeling(seed, d):
        rng = np.random.default_rng(seed)
        b = np.tril(rng.normal(size=(d, d)), k=-1).astype(np.float32)
        order = np.arange(d, dtype=np.int32)
        t = np.asarray(
            effects.total_effects_impl(jnp.asarray(b), jnp.asarray(order))
        )
        perm = rng.permutation(d)
        inv = np.empty(d, dtype=np.int32)
        inv[perm] = np.arange(d, dtype=np.int32)
        # Relabeled system: variable i is old variable perm[i].
        b_p = b[np.ix_(perm, perm)].astype(np.float32)
        order_p = inv[order]
        t_p = np.asarray(
            effects.total_effects_impl(
                jnp.asarray(b_p), jnp.asarray(order_p)
            )
        )
        np.testing.assert_allclose(
            t_p, t[np.ix_(perm, perm)], atol=1e-5
        )
