"""Functional core (api.fit_fn) + vmap-batched engine (batched / bootstrap).

Covers the PR's contracts: the vmap bootstrap bit-matches the loop
fallback under a fixed seed, ``fit_many`` agrees with per-dataset
``fit_fn``, ``FitResult`` is a stable pytree, in-trace staged compaction
reproduces the full-scan order, and ``bootstrap_lingam(model=...)``
honors *all* estimator settings (regression: backend/interpret used to be
silently dropped)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, batched
from repro.core.bootstrap import _resolve_config, bootstrap_lingam
from repro.core.direct_lingam import DirectLiNGAM
from repro.core.ordering import causal_order, causal_order_compact
from repro.data.simulate import simulate_lingam


def test_fit_fn_matches_facade():
    gt = simulate_lingam(m=1500, d=7, seed=0)
    model = DirectLiNGAM(backend="blocked", prune_threshold=0.1).fit(gt.data)
    res = api.fit_fn(
        jnp.asarray(gt.data),
        api.FitConfig(backend="blocked", prune_threshold=0.1),
    )
    assert np.array_equal(model.causal_order_, np.asarray(res.order))
    np.testing.assert_array_equal(model.adjacency_, np.asarray(res.adjacency))
    assert np.all(np.asarray(res.resid_var) > 0)


def test_fit_many_matches_per_dataset_fit_fn():
    xs = jnp.stack([
        jnp.asarray(simulate_lingam(m=600, d=5, seed=s).data)
        for s in range(3)
    ])
    config = api.FitConfig(backend="blocked")
    many = batched.fit_many(xs, config)
    for s in range(3):
        one = api.fit_fn(xs[s], config)
        assert np.array_equal(np.asarray(many.order[s]), np.asarray(one.order))
        np.testing.assert_allclose(
            np.asarray(many.adjacency[s]), np.asarray(one.adjacency),
            atol=1e-5,
        )


def test_fitresult_is_stable_pytree():
    res = api.FitResult(
        order=jnp.arange(4, dtype=jnp.int32),
        adjacency=jnp.eye(4),
        resid_var=jnp.ones(4),
    )
    leaves, treedef = jax.tree_util.tree_flatten(res)
    assert len(leaves) == 3
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert np.array_equal(np.asarray(back.order), np.asarray(res.order))
    assert np.array_equal(
        np.asarray(back.adjacency), np.asarray(res.adjacency)
    )
    # Round-trips through jit boundaries as a return type.
    out = jax.jit(lambda r: jax.tree.map(lambda v: v + 0, r))(res)
    assert isinstance(out, api.FitResult)
    assert treedef == jax.tree_util.tree_structure(out)


@pytest.mark.parametrize("compaction", ["none", "staged"])
def test_vmap_bootstrap_matches_loop(compaction):
    """Same seed + same explicit config => identical resamples, identical
    edge probabilities (and matching coefficients) across strategies."""
    gt = simulate_lingam(m=500, d=6, seed=4)
    config = api.FitConfig(backend="blocked", compaction=compaction)
    kw = dict(n_sampling=6, threshold=0.1, seed=0, config=config)
    res_v = bootstrap_lingam(gt.data, strategy="vmap", **kw)
    res_l = bootstrap_lingam(gt.data, strategy="loop", **kw)
    np.testing.assert_array_equal(res_v.edge_prob, res_l.edge_prob)
    np.testing.assert_allclose(res_v.coef_mean, res_l.coef_mean, atol=1e-5)
    np.testing.assert_allclose(res_v.coef_std, res_l.coef_std, atol=1e-5)


def test_default_strategies_agree_on_edge_prob():
    """Shipped defaults (vmap+staged vs loop+full scan): the compaction
    schedule returns the identical causal order, so the thresholded edge
    probabilities agree bit-for-bit."""
    gt = simulate_lingam(m=800, d=12, seed=1)
    kw = dict(n_sampling=5, threshold=0.1, seed=3)
    res_v = bootstrap_lingam(gt.data, strategy="vmap", **kw)
    res_l = bootstrap_lingam(gt.data, strategy="loop", **kw)
    np.testing.assert_array_equal(res_v.edge_prob, res_l.edge_prob)


def test_auto_strategy_falls_back_to_loop_on_memory():
    """auto = vmap when the resample stack fits the budget, else loop."""
    gt = simulate_lingam(m=400, d=5, seed=3)
    kw = dict(n_sampling=3, threshold=0.1, seed=0)
    # tiny budget forces the loop path; default budget takes vmap — both
    # fit identical resamples so the summaries agree.
    res_loop = bootstrap_lingam(gt.data, max_vmap_bytes=1, **kw)
    res_vmap = bootstrap_lingam(gt.data, **kw)
    np.testing.assert_array_equal(res_loop.edge_prob, res_vmap.edge_prob)


def test_compaction_frac_validated():
    with pytest.raises(ValueError, match="frac"):
        api.fit_fn(
            jnp.zeros((50, 12)),
            api.FitConfig(compaction="staged", compaction_frac=1.5),
        )


def test_resample_indices_deterministic_and_on_device():
    idx1 = batched.resample_indices(7, 4, 100)
    idx2 = batched.resample_indices(7, 4, 100)
    assert isinstance(idx1, jax.Array)
    assert np.array_equal(np.asarray(idx1), np.asarray(idx2))
    assert idx1.shape == (4, 100)
    assert int(idx1.min()) >= 0 and int(idx1.max()) < 100


def test_compact_ordering_matches_full_scan():
    gt = simulate_lingam(m=1200, d=13, seed=5)
    full = np.asarray(causal_order(gt.data, backend="blocked"))
    compact = np.asarray(
        causal_order_compact(gt.data, backend="blocked", min_stage=3)
    )
    assert np.array_equal(full, compact), (full, compact)


def test_bootstrap_model_settings_honored():
    """model=... adopts every estimator setting, not just prune fields."""
    model = DirectLiNGAM(
        backend="pallas",
        interpret=True,
        prune_method="adaptive_lasso",
        prune_threshold=0.05,
        prune_kwargs={"lam": 0.02},
        compaction="staged",
    )
    cfg = _resolve_config("blocked", model, None, "vmap")
    assert cfg.backend == "pallas"
    assert cfg.interpret is True
    assert cfg.prune_method == "adaptive_lasso"
    assert cfg.prune_threshold == 0.05
    assert cfg.prune_kwargs_dict == {"lam": 0.02}
    # the model's ordering schedule is adopted verbatim, per strategy
    assert cfg.compaction == "staged"
    plain = DirectLiNGAM(backend="blocked")
    assert _resolve_config("blocked", plain, None, "vmap").compaction == "none"
    # explicit config always wins
    explicit = api.FitConfig(backend="ref")
    assert _resolve_config("blocked", model, explicit, "loop") is explicit


def test_pairwise_moments_batched_entry():
    """ops.pairwise_moments with a leading batch axis matches per-element
    calls (kernel-level batching entry point)."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    xs = rng.laplace(size=(3, 200, 6)).astype(np.float32)
    xs_std = jnp.stack([ops.standardize(jnp.asarray(x)) for x in xs])
    cs = jnp.stack([ops.correlation(x) for x in xs_std])
    m1b, m2b = ops.pairwise_moments(xs_std, cs, backend="blocked")
    assert m1b.shape == (3, 6, 6)
    for s in range(3):
        m1, m2 = ops.pairwise_moments(xs_std[s], cs[s], backend="blocked")
        np.testing.assert_allclose(np.asarray(m1b[s]), np.asarray(m1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(m2b[s]), np.asarray(m2), atol=1e-6)


def test_bootstrap_model_pallas_runs_end_to_end():
    gt = simulate_lingam(m=400, d=5, seed=2)
    model = DirectLiNGAM(backend="pallas", interpret=True)
    res = bootstrap_lingam(
        gt.data, n_sampling=3, threshold=0.1, seed=0, model=model,
        strategy="loop",
    )
    assert res.edge_prob.shape == (5, 5)
    assert res.n_sampling == 3


def test_serve_causal_engine_batches_by_shape():
    from repro.serve.engine import CausalDiscoveryEngine, FitRequest

    reqs = [
        FitRequest(data=simulate_lingam(m=400, d=5, seed=s).data)
        for s in range(3)
    ] + [FitRequest(data=simulate_lingam(m=300, d=4, seed=9).data)]
    engine = CausalDiscoveryEngine(
        api.FitConfig(backend="blocked"), batch_size=2
    )
    out = engine.run(reqs)
    for r in out:
        d = r.data.shape[1]
        assert r.result is not None
        assert r.result.adjacency.shape == (d, d)
        assert sorted(np.asarray(r.result.order).tolist()) == list(range(d))
    # engine result matches a direct fit with the same config
    one = api.fit_fn(jnp.asarray(reqs[0].data), api.FitConfig(backend="blocked"))
    assert np.array_equal(np.asarray(one.order), reqs[0].result.order)
