"""Streaming subsystem: moment store, rolling VarLiNGAM, serving sessions.

Covers the streaming PR's contracts:

  * ``MomentState`` algebra — merge is associative/commutative, merged
    states match the direct two-pass computation, and
    ``update_chunk`` + ``retract_chunk`` round-trips within fp32
    tolerance (hypothesis property tests where available).
  * the chunked kernel entry (``pairwise_moments_chunked``) agrees with
    the whole-slab backends, and ``FitConfig.moment_chunk`` reproduces
    the plain fit bit-for-bit.
  * ``api.fit_from_stats`` matches ``api.fit_fn`` given the dataset's
    own moments (both pruning methods), and rejects mesh partitions.
  * the parity pin: rolling-window refits (merge/retract state) equal
    the from-scratch window oracle (direct two-pass) across slides that
    exercise retraction.
  * the serving engine batches due sessions' refits through
    ``fit_many_from_stats`` with results identical to the
    single-session path, and reports sane graph deltas.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, batched
from repro.data.simulate import simulate_lingam, simulate_var_stocks
from repro.kernels import ops
from repro.obs import compile_log
from repro.serve import engine as serve_engine
from repro.serve.engine import CausalDiscoveryEngine
from repro.stream import StreamConfig, session as session_lib, stats, window

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - minimal envs
    HAVE_HYPOTHESIS = False

_CFG = api.FitConfig(backend="blocked", compaction="staged")


def _np_state(x):
    """Reference two-pass (count, mean, m2) in float64."""
    x = np.asarray(x, np.float64)
    mu = x.mean(axis=0)
    xc = x - mu
    return float(len(x)), mu, xc.T @ xc


def _assert_state_close(s, n, mu, m2, *, atol_mean=1e-4, atol_m2=None):
    scale = max(1.0, float(np.abs(m2).max()))
    atol_m2 = atol_m2 if atol_m2 is not None else 1e-4 * scale
    assert float(s.count) == pytest.approx(n)
    np.testing.assert_allclose(np.asarray(s.mean), mu, atol=atol_mean)
    np.testing.assert_allclose(np.asarray(s.m2), m2, atol=atol_m2)


def _chunks(rng, n_chunks, d, lo=20, hi=80):
    return [
        (rng.laplace(size=(int(rng.integers(lo, hi)), d))
         * rng.uniform(0.5, 3.0, d)
         + rng.uniform(-2.0, 2.0, d)).astype(np.float32)
        for _ in range(n_chunks)
    ]


# ----------------------------------------------------------------------
# MomentState algebra
# ----------------------------------------------------------------------


def test_from_chunk_matches_numpy_two_pass():
    rng = np.random.default_rng(0)
    x = _chunks(rng, 1, 6, 100, 101)[0]
    s = stats.from_chunk(jnp.asarray(x))
    _assert_state_close(s, *_np_state(x))
    cov = np.cov(x.T, ddof=0)
    np.testing.assert_allclose(
        np.asarray(stats.covariance(s)), cov, atol=1e-4
    )


def test_init_is_merge_identity():
    rng = np.random.default_rng(1)
    x = _chunks(rng, 1, 4)[0]
    s = stats.from_chunk(jnp.asarray(x))
    for merged in (stats.merge(stats.init(4), s), stats.merge(s, stats.init(4))):
        _assert_state_close(merged, *_np_state(x))


def test_retract_everything_zeroes_state():
    rng = np.random.default_rng(2)
    x = _chunks(rng, 1, 3)[0]
    s = stats.retract_chunk(stats.update_chunk(stats.init(3), x), x)
    assert float(s.count) == 0.0
    assert np.all(np.isfinite(np.asarray(s.mean)))
    assert np.all(np.isfinite(np.asarray(s.m2)))


if HAVE_HYPOTHESIS:
    _SETTINGS = dict(max_examples=20, deadline=None, derandomize=True)

    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 8))
    @settings(**_SETTINGS)
    def test_merge_commutative(seed, d):
        rng = np.random.default_rng(seed)
        a, b = (stats.from_chunk(jnp.asarray(c)) for c in _chunks(rng, 2, d))
        ab, ba = stats.merge(a, b), stats.merge(b, a)
        _assert_state_close(
            ba, float(ab.count), np.asarray(ab.mean), np.asarray(ab.m2)
        )

    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 8))
    @settings(**_SETTINGS)
    def test_merge_associative_and_matches_direct(seed, d):
        rng = np.random.default_rng(seed)
        ca, cb, cc = _chunks(rng, 3, d)
        a, b, c = (stats.from_chunk(jnp.asarray(x)) for x in (ca, cb, cc))
        left = stats.merge(stats.merge(a, b), c)
        right = stats.merge(a, stats.merge(b, c))
        n, mu, m2 = _np_state(np.concatenate([ca, cb, cc]))
        _assert_state_close(left, n, mu, m2)
        _assert_state_close(right, n, mu, m2)

    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 8))
    @settings(**_SETTINGS)
    def test_update_retract_roundtrip(seed, d):
        """A rolling slide (absorb b, later retract b) lands back on the
        direct two-pass state of a — within fp32 tolerance."""
        rng = np.random.default_rng(seed)
        ca, cb = _chunks(rng, 2, d)
        s = stats.update_chunk(
            stats.update_chunk(stats.init(d), ca), cb
        )
        back = stats.retract_chunk(s, cb)
        _assert_state_close(back, *_np_state(ca))


# ----------------------------------------------------------------------
# Chunked kernel entry + moment_chunk config
# ----------------------------------------------------------------------


@pytest.mark.parametrize("m,d,chunk", [(257, 7, 64), (128, 8, 128), (64, 5, 100)])
def test_chunked_moments_match_blocked(m, d, chunk):
    rng = np.random.default_rng(0)
    x = rng.laplace(size=(m, d)).astype(np.float32)
    xs = ops.standardize(jnp.asarray(x))
    c = ops.correlation(xs)
    m1a, m2a = ops.pairwise_moments(xs, c, backend="blocked")
    m1b, m2b = ops.pairwise_moments_chunked(xs, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(m1a), np.asarray(m1b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2a), np.asarray(m2b), atol=1e-5)


def test_chunked_moments_pallas_interpret():
    rng = np.random.default_rng(3)
    x = rng.laplace(size=(128, 8)).astype(np.float32)
    xs = ops.standardize(jnp.asarray(x))
    c = ops.correlation(xs)
    m1a, m2a = ops.pairwise_moments(xs, c, backend="blocked")
    m1b, m2b = ops.pairwise_moments_chunked(
        xs, c, chunk=64, backend="pallas", interpret=True
    )
    np.testing.assert_allclose(np.asarray(m1a), np.asarray(m1b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2a), np.asarray(m2b), atol=1e-5)


def test_moment_chunk_config_validation():
    with pytest.raises(ValueError, match="moment_chunk"):
        api.FitConfig(backend="ref", moment_chunk=64)
    with pytest.raises(ValueError, match="moment_chunk"):
        api.FitConfig(backend="blocked", moment_chunk=0)


def test_moment_chunk_config_reproduces_plain_fit():
    gt = simulate_lingam(m=900, d=7, seed=2)
    x = jnp.asarray(gt.data)
    plain = api.fit_fn(x, _CFG)
    chunked = api.fit_fn(x, dataclasses.replace(_CFG, moment_chunk=128))
    assert np.array_equal(np.asarray(plain.order), np.asarray(chunked.order))
    np.testing.assert_allclose(
        np.asarray(plain.adjacency), np.asarray(chunked.adjacency), atol=1e-6
    )


# ----------------------------------------------------------------------
# from_stats fit path
# ----------------------------------------------------------------------


@pytest.mark.parametrize("method", ["ols", "adaptive_lasso"])
def test_fit_from_stats_matches_fit_fn(method):
    gt = simulate_lingam(m=1200, d=7, seed=0)
    x = jnp.asarray(gt.data)
    mu = jnp.mean(x, axis=0)
    xc = x - mu[None, :]
    cov = (xc.T @ xc) / x.shape[0]
    cfg = dataclasses.replace(_CFG, prune_method=method)
    full = api.fit_fn(x, cfg)
    from_stats = api.fit_from_stats(x, mu, cov, cfg)
    assert np.array_equal(
        np.asarray(full.order), np.asarray(from_stats.order)
    )
    np.testing.assert_allclose(
        np.asarray(full.adjacency), np.asarray(from_stats.adjacency),
        atol=2e-4,
    )
    # diag((I-B) cov (I-B)^T) equals the empirical residual variance.
    np.testing.assert_allclose(
        np.asarray(full.resid_var), np.asarray(from_stats.resid_var),
        rtol=1e-3, atol=1e-5,
    )


def test_fit_from_stats_rejects_partition():
    cfg = api.FitConfig(partition=api.Partition())
    with pytest.raises(ValueError, match="mesh"):
        api.fit_from_stats(
            jnp.zeros((32, 4)), jnp.zeros(4), jnp.eye(4), cfg
        )


def test_fit_many_from_stats_matches_single():
    xs, mus, covs = [], [], []
    for s in range(3):
        x = jnp.asarray(simulate_lingam(m=500, d=5, seed=s).data)
        mu = jnp.mean(x, axis=0)
        xc = x - mu[None, :]
        xs.append(x)
        mus.append(mu)
        covs.append((xc.T @ xc) / x.shape[0])
    many = batched.fit_many_from_stats(
        jnp.stack(xs), jnp.stack(mus), jnp.stack(covs), _CFG
    )
    for s in range(3):
        one = api.fit_from_stats(xs[s], mus[s], covs[s], _CFG)
        assert np.array_equal(
            np.asarray(many.order[s]), np.asarray(one.order)
        )
        np.testing.assert_allclose(
            np.asarray(many.adjacency[s]), np.asarray(one.adjacency),
            atol=1e-5,
        )


# ----------------------------------------------------------------------
# Rolling-window VarLiNGAM: the parity pin
# ----------------------------------------------------------------------


def _stock_chunks(d, chunk, n_chunks, seed=1):
    x, _, _ = simulate_var_stocks(
        m=chunk * n_chunks + 5, d=d, edge_prob=0.3, seed=seed
    )
    return [x[k * chunk:(k + 1) * chunk] for k in range(n_chunks)]


def test_rolling_matches_direct_window_oracle():
    """Rolling refit (merged + retracted moments) == from-scratch window
    refit (direct two-pass) at every slide, including post-retraction."""
    d, chunk, wc = 8, 96, 4
    roll = window.RollingVarLiNGAM(d, chunk, wc, lags=1, config=_CFG)
    n_checked = 0
    for rows in _stock_chunks(d, chunk, wc + 3):
        roll.push(rows)
        if not roll.ready:
            continue
        got = roll.refit()
        want = window.direct_window_fit(
            list(roll.ring), roll._lead_tail, lags=1, config=roll.config
        )
        assert np.array_equal(
            np.asarray(got.result.order), np.asarray(want.result.order)
        )
        np.testing.assert_allclose(
            np.asarray(got.result.adjacency),
            np.asarray(want.result.adjacency),
            atol=1e-4,
        )
        for th_got, th_want in zip(got.thetas, want.thetas):
            np.testing.assert_allclose(th_got, th_want, atol=1e-4)
        n_checked += 1
    assert n_checked == 4  # 3 of these exercised retraction


def test_rolling_var_close_to_lstsq():
    """State-derived VAR coefficients track the legacy lstsq estimate."""
    from repro.core.var_lingam import estimate_var

    d, chunk, wc = 6, 128, 4
    chunks = _stock_chunks(d, chunk, wc, seed=3)
    roll = window.RollingVarLiNGAM(d, chunk, wc, lags=1, config=_CFG)
    for rows in chunks:
        roll.push(rows)
    plan = roll.prepare_refit()
    mats, _, _ = estimate_var(np.concatenate(chunks), lags=1)
    np.testing.assert_allclose(
        plan.mats[0], np.asarray(mats[0]), atol=5e-3
    )


def test_rolling_reanchor_preserves_estimate():
    d, chunk, wc = 6, 80, 3
    chunks = _stock_chunks(d, chunk, wc + 2, seed=5)
    roll = window.RollingVarLiNGAM(d, chunk, wc, lags=1, config=_CFG)
    anchored = window.RollingVarLiNGAM(
        d, chunk, wc, lags=1, config=_CFG, reanchor_every=1
    )
    for rows in chunks:
        roll.push(rows)
        anchored.push(rows)
    a, b = roll.refit(), anchored.refit()
    assert np.array_equal(
        np.asarray(a.result.order), np.asarray(b.result.order)
    )
    np.testing.assert_allclose(
        np.asarray(a.result.adjacency), np.asarray(b.result.adjacency),
        atol=1e-4,
    )


def test_rolling_push_copies_caller_buffer():
    """A client reusing one chunk buffer across posts must not corrupt
    the ring (push copies; regression for the aliasing bug)."""
    d, chunk, wc = 6, 64, 3
    chunks = _stock_chunks(d, chunk, wc, seed=9)
    reused = window.RollingVarLiNGAM(d, chunk, wc, lags=1, config=_CFG)
    fresh = window.RollingVarLiNGAM(d, chunk, wc, lags=1, config=_CFG)
    buf = np.empty((chunk, d), np.float32)
    for rows in chunks:
        buf[:] = rows
        reused.push(buf)
        fresh.push(rows)
    a, b = reused.refit(), fresh.refit()
    assert np.array_equal(
        np.asarray(a.result.order), np.asarray(b.result.order)
    )
    np.testing.assert_array_equal(
        np.asarray(a.result.adjacency), np.asarray(b.result.adjacency)
    )


def test_rolling_validates_inputs():
    with pytest.raises(ValueError, match="chunk"):
        window.RollingVarLiNGAM(4, 1, 3, lags=1)
    with pytest.raises(ValueError, match="partition"):
        window.RollingVarLiNGAM(
            4, 32, 3,
            config=api.FitConfig(partition=api.Partition()),
        )
    roll = window.RollingVarLiNGAM(4, 32, 3)
    with pytest.raises(RuntimeError, match="not full"):
        roll.refit()
    with pytest.raises(ValueError, match="expected"):
        roll.push(np.zeros((16, 4), np.float32))


# ----------------------------------------------------------------------
# Sessions + engine batching
# ----------------------------------------------------------------------


def _stream_config(d, chunk, wc, **kw):
    return StreamConfig(
        d=d, chunk=chunk, window_chunks=wc, lags=1, fit=_CFG, **kw
    )


def test_graph_delta_edge_sets():
    prev = np.array([[0.0, 0.5], [0.0, 0.0]])
    new = np.array([[0.0, 0.0], [0.8, 0.0]])
    delta = session_lib.graph_delta(prev, new, 0.1, refit_index=3)
    assert delta.refit_index == 3
    assert delta.n_edges == 1
    assert [tuple(e) for e in delta.added] == [(1, 0)]
    assert [tuple(e) for e in delta.removed] == [(0, 1)]
    assert delta.max_abs_change == pytest.approx(0.8)
    first = session_lib.graph_delta(None, new, 0.1, refit_index=0)
    assert first.n_edges == 1 and len(first.removed) == 0


def test_engine_streams_batch_and_match_single_session():
    d, chunk, wc = 8, 96, 4
    cfg = _stream_config(d, chunk, wc)
    eng = CausalDiscoveryEngine(batch_size=2)
    all_chunks = [_stock_chunks(d, chunk, wc + 2, seed=s) for s in (1, 2)]
    sids = [eng.open_stream(cfg) for _ in all_chunks]
    deltas = []
    for k in range(wc + 2):
        for sid, chunks in zip(sids, all_chunks):
            deltas += eng.post_chunk(sid, chunks[k])
    # Session 0 flushes solo at window fill (session 1 is still filling
    # and must not delay it); thereafter each round batches both
    # sessions' due refits into one program, with session 1's final
    # refit left pending for the explicit drain.
    assert len(deltas) == 5
    deltas += eng.flush_streams()
    assert len(deltas) == 6
    assert deltas[0][1].refit_index == 0 and deltas[-1][1].refit_index == 2

    # Engine's batched refit == the standalone rolling path on the same
    # rows (vmap-vs-single tolerance).
    roll = window.RollingVarLiNGAM(d, chunk, wc, lags=1, config=cfg.fit)
    for rows in all_chunks[0]:
        roll.push(rows)
    solo = roll.refit()
    served = eng.stream_session(sids[0]).last_fit
    assert np.array_equal(
        np.asarray(solo.result.order), np.asarray(served.result.order)
    )
    np.testing.assert_allclose(
        np.asarray(solo.result.adjacency),
        np.asarray(served.result.adjacency),
        atol=1e-5,
    )
    closed = eng.close_stream(sids[0])
    assert closed.n_refits == 3
    assert sids[0] not in eng._streams


def test_engine_idle_filling_session_does_not_starve_active():
    """A session still filling its window must not block auto-flush for
    sessions that are due (regression: liveness under stalled clients)."""
    d, chunk, wc = 6, 64, 3
    cfg = _stream_config(d, chunk, wc)
    eng = CausalDiscoveryEngine(batch_size=8)
    active = eng.open_stream(cfg)
    eng.open_stream(cfg)  # never posts; window never fills
    chunks = _stock_chunks(d, chunk, wc + 2, seed=11)
    deltas = []
    for rows in chunks:
        deltas += eng.post_chunk(active, rows)
    assert len(deltas) == 3
    assert all(sid == active for sid, _ in deltas)


def test_engine_ready_idle_session_defers_at_most_one_post():
    """A ready-but-idle peer may defer an active session's due refit by
    one of its own posts, never indefinitely (bounded-deferral rule)."""
    d, chunk, wc = 6, 64, 3
    cfg = _stream_config(d, chunk, wc)
    eng = CausalDiscoveryEngine(batch_size=8)
    active, idle = eng.open_stream(cfg), eng.open_stream(cfg)
    chunks = _stock_chunks(d, chunk, wc + 4, seed=13)
    for rows in chunks[:wc]:  # both windows fill; idle stops posting
        eng.post_chunk(idle, rows)
        eng.post_chunk(active, rows)
    eng.flush_streams()
    n_refits_before = eng.stream_session(active).n_refits
    deltas = []
    for rows in chunks[wc:]:  # 4 posts from the active session only
        deltas += eng.post_chunk(active, rows)
    assert all(sid == active for sid, _ in deltas)
    # Due after post 1, flushed at post 2; due at 3, flushed at 4.
    assert len(deltas) == 2
    assert eng.stream_session(active).n_refits == n_refits_before + 2


def test_engine_refit_every_throttles():
    d, chunk, wc = 6, 64, 3
    cfg = _stream_config(d, chunk, wc, refit_every=2)
    eng = CausalDiscoveryEngine(batch_size=1)
    sid = eng.open_stream(cfg)
    chunks = _stock_chunks(d, chunk, wc + 4, seed=7)
    n_deltas = sum(len(eng.post_chunk(sid, rows)) for rows in chunks)
    # Ready after wc pushes; 4 more pushes at refit_every=2 -> 2 refits.
    assert n_deltas == 2
    assert eng.stream_session(sid).n_refits == 2


def test_engine_flush_compiles_once_per_shape_bucket():
    """A steady flush cadence reuses the batched refit program: after
    the warmup rounds have traced each (bucket, shape) signature —
    visible in the public ``repro.obs.compile_log`` — further full
    rounds add zero compile events."""
    d, chunk, wc = 5, 48, 3  # unique dims so other tests' caches can't mask
    cfg = _stream_config(d, chunk, wc)
    eng = CausalDiscoveryEngine(batch_size=4)
    all_chunks = [_stock_chunks(d, chunk, wc + 6, seed=s) for s in (21, 22)]
    sids = [eng.open_stream(cfg) for _ in all_chunks]
    n0 = compile_log.total("batched.fit_many_from_stats")
    for k in range(wc + 2):
        for sid, chunks in zip(sids, all_chunks):
            eng.post_chunk(sid, chunks[k])
    eng.flush_streams()
    n_warm = compile_log.total("batched.fit_many_from_stats")
    assert n_warm > n0  # the fill/steady shape signatures traced once...
    for k in range(wc + 2, wc + 4):
        for sid, chunks in zip(sids, all_chunks):
            eng.post_chunk(sid, chunks[k])
    eng.flush_streams()
    # ...and two more full rounds replay them without re-tracing.
    assert compile_log.total("batched.fit_many_from_stats") == n_warm
    assert not eng.last_flush_errors


def test_engine_flush_isolates_failing_session(monkeypatch):
    """One session failing to build its refit plan must not abort the
    flush: peers refit, the failure lands in ``last_flush_errors`` as a
    structured event, and the broken session stays due (retryable)."""
    d, chunk, wc = 6, 64, 3
    cfg = _stream_config(d, chunk, wc)
    eng = CausalDiscoveryEngine(batch_size=8)
    good, bad = eng.open_stream(cfg), eng.open_stream(cfg)
    chunks = _stock_chunks(d, chunk, wc, seed=31)
    for rows in chunks:  # fill both windows; both become due
        eng.stream_session(good).post(rows)
        eng.stream_session(bad).post(rows)

    def boom():
        raise RuntimeError("poisoned moment state")

    monkeypatch.setattr(
        eng.stream_session(bad).rolling, "prepare_refit", boom
    )
    out = eng.flush_streams()
    assert [sid for sid, _ in out] == [good]
    (err,) = eng.last_flush_errors
    assert (err.sid, err.stage) == (bad, "prepare")
    assert isinstance(err.error, RuntimeError)
    assert "poisoned" in err.summary()
    assert eng.stream_session(bad).due  # still due: next flush retries


def test_engine_flush_falls_back_per_session_on_bucket_failure(monkeypatch):
    """A whole-bucket program failure degrades to per-session refits —
    every session still gets its delta, and the bucket-level error is
    recorded with sid='*'."""
    d, chunk, wc = 6, 64, 3
    cfg = _stream_config(d, chunk, wc)
    eng = CausalDiscoveryEngine(batch_size=8)
    sids = [eng.open_stream(cfg) for _ in range(2)]
    for k, rows in enumerate(_stock_chunks(d, chunk, wc, seed=33)):
        for sid in sids:
            eng.stream_session(sid).post(rows)

    def boom(*a, **kw):
        raise RuntimeError("bucket program OOM")

    monkeypatch.setattr(
        serve_engine.lingam_batched, "fit_many_from_stats", boom
    )
    out = eng.flush_streams()
    assert sorted(sid for sid, _ in out) == sorted(sids)
    (err,) = eng.last_flush_errors
    assert (err.sid, err.stage) == ("*", "fit")
    assert all(not eng.stream_session(sid).due for sid in sids)