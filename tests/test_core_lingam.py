"""End-to-end behaviour of the parallel DirectLiNGAM / VarLiNGAM vs the
sequential reference and the simulated ground truth (paper Fig. 3, §3.1)."""

import numpy as np
import pytest

from repro.baselines import sequential_lingam as seq
from repro.core import DirectLiNGAM, VarLiNGAM
from repro.core.ordering import causal_order
from repro.data.simulate import simulate_lingam, simulate_var_stocks


def _order_consistent(order, b_true):
    """No edge may point from a later to an earlier variable."""
    d = len(order)
    pos = np.empty(d, int)
    pos[np.asarray(order)] = np.arange(d)
    src, dst = np.nonzero(b_true)  # b[i, j] != 0: j -> i
    return bool(np.all(pos[dst] < pos[src]))


def _f1_shd(b_est, b_true, thresh=0.1):
    e = np.abs(b_est) > thresh
    t = b_true != 0
    tp = np.sum(e & t)
    fp = np.sum(e & ~t)
    fn = np.sum(~e & t)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    shd = fp + fn
    return f1, rec, shd


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_parallel_matches_sequential_order(seed):
    gt = simulate_lingam(m=2000, d=7, seed=seed)
    o_seq = seq.causal_order_sequential(gt.data)
    o_par = np.asarray(causal_order(gt.data, backend="blocked"))
    assert np.array_equal(o_seq, o_par)


def test_pallas_backend_matches_blocked():
    gt = simulate_lingam(m=1500, d=8, seed=3)
    o_b = np.asarray(causal_order(gt.data, backend="blocked"))
    o_p = np.asarray(causal_order(gt.data, backend="pallas", interpret=True))
    assert np.array_equal(o_b, o_p)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_recovers_true_dag(seed):
    gt = simulate_lingam(m=5000, d=10, seed=seed)
    model = DirectLiNGAM(backend="blocked", prune_threshold=0.1).fit(gt.data)
    assert _order_consistent(model.causal_order_, gt.adjacency)
    f1, rec, shd = _f1_shd(model.adjacency_, gt.adjacency)
    assert f1 > 0.9, (f1, shd)


def test_adjacency_close_to_truth():
    gt = simulate_lingam(m=20000, d=8, seed=5)
    model = DirectLiNGAM(backend="blocked").fit(gt.data)
    if _order_consistent(model.causal_order_, gt.adjacency):
        err = np.max(np.abs(model.adjacency_ - gt.adjacency))
        assert err < 0.1, err


def test_adaptive_lasso_sparsifies():
    gt = simulate_lingam(m=5000, d=8, seed=7)
    m_ols = DirectLiNGAM(backend="blocked", prune_method="ols").fit(gt.data)
    m_al = DirectLiNGAM(
        backend="blocked",
        prune_method="adaptive_lasso",
        prune_kwargs=dict(lam=0.05),
    ).fit(gt.data)
    nz_true = np.sum(gt.adjacency != 0)
    nz_al = np.sum(np.abs(m_al.adjacency_) > 1e-3)
    nz_ols = np.sum(np.abs(m_ols.adjacency_) > 1e-3)
    assert nz_al <= nz_ols
    assert nz_al >= nz_true * 0.5


def test_ols_matches_sequential_numpy():
    gt = simulate_lingam(m=3000, d=6, seed=11)
    order, b_seq = seq.fit_sequential(gt.data)
    model = DirectLiNGAM(backend="blocked").fit(gt.data)
    assert np.array_equal(order, model.causal_order_)
    np.testing.assert_allclose(model.adjacency_, b_seq, atol=2e-3)


def test_var_lingam_recovers_structure():
    x, b0, m1 = simulate_var_stocks(m=8000, d=12, edge_prob=0.15, seed=0)
    model = VarLiNGAM(lags=1, prune_threshold=0.1).fit(x)
    f1_b0, _, _ = _f1_shd(model.adjacency_matrices_[0], b0, thresh=0.1)
    assert f1_b0 > 0.7, f1_b0
    # Lagged matrix should correlate with the ground truth.
    th1 = model.adjacency_matrices_[1]
    mask = m1 != 0
    if mask.sum() > 0:
        err = np.abs(th1[mask] - m1[mask]).mean()
        assert err < 0.2, err


@pytest.mark.parametrize("seed", [0, 5])
def test_staged_compaction_matches_full(seed):
    """Active-set compaction (§Perf) must produce the identical order."""
    from repro.core.ordering import causal_order_compact

    gt = simulate_lingam(m=1500, d=13, seed=seed)
    full = np.asarray(causal_order(gt.data, backend="blocked"))
    compact = np.asarray(
        causal_order_compact(gt.data, backend="blocked", min_stage=3)
    )
    assert np.array_equal(full, compact), (full, compact)


def test_causal_order_staged_deprecated_shim():
    """The retired host-driven staging warns and delegates to the
    in-trace compaction (identical order)."""
    from repro.core.ordering import causal_order_compact, causal_order_staged

    gt = simulate_lingam(m=1000, d=9, seed=1)
    with pytest.warns(DeprecationWarning, match="causal_order_compact"):
        staged = np.asarray(causal_order_staged(gt.data, min_stage=3))
    compact = np.asarray(causal_order_compact(gt.data, min_stage=3))
    assert np.array_equal(staged, compact)


def test_ica_lingam_baseline_recovers():
    """The original ICA-LiNGAM (2006) baseline recovers simple DAGs —
    the in-family comparison point for DirectLiNGAM."""
    from repro.baselines.ica_lingam import ICALiNGAM

    gt = simulate_lingam(m=8000, d=6, seed=2)
    model = ICALiNGAM(n_steps=300, prune_threshold=0.1).fit(gt.data)
    f1, rec, shd = _f1_shd(model.adjacency_, gt.adjacency)
    assert f1 > 0.7, (f1, shd)


def test_bootstrap_edge_probabilities():
    """Bootstrap: true edges get high presence probability, non-edges low."""
    from repro.core.bootstrap import bootstrap_lingam

    gt = simulate_lingam(m=3000, d=6, seed=4)
    res = bootstrap_lingam(gt.data, n_sampling=8, threshold=0.1, seed=0)
    true = gt.adjacency != 0
    assert res.edge_prob[true].mean() > 0.8, res.edge_prob[true]
    assert res.edge_prob[~true].mean() < 0.2, res.edge_prob[~true].mean()
    edges = res.stable_edges(min_prob=0.7)
    assert len(edges) >= true.sum() * 0.5
