"""Gene-regulatory discovery with interventions (paper §4.1, Table 1).

    PYTHONPATH=src python examples/gene_discovery.py [--full]

Synthetic Perturb-seq-like data (the real Perturb-CITE-seq is not available
offline): single-gene interventions, 80/20 train/held-out split,
DirectLiNGAM + Stein-VI scoring of interventional NLL / MAE.
"""

import argparse

from benchmarks.bench_gene import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale d=961 (slow on CPU)")
    args = ap.parse_args()
    results = run(quick=not args.full)
    print("\nSummary (lower is better):")
    for method, r in results.items():
        print(f"  {method:14s} I-NLL={r['inll']:.3f}  I-MAE={r['imae']:.3f}")


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
