"""LiNGAM x LM integration: causal analysis of transformer activations.

    PYTHONPATH=src python examples/activation_causality.py

Trains a tiny LM briefly, collects per-layer mean activations over a probe
batch, and runs DirectLiNGAM over the layer features to estimate the
causal (information-flow) ordering across layers — the integration point
between the paper's technique and the LM substrate (DESIGN.md §4).
A sanity property: the discovered causal order should correlate with
layer depth (information flows forward).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import DirectLiNGAM
from repro.models import layers, model as model_lib


def collect_layer_features(cfg, params, tokens):
    """Mean-pooled activation per layer per sequence: (B, n_layers)."""
    x = layers.embed(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])
    feats = []
    pattern = model_lib.layer_pattern(cfg)
    for g in range(model_lib.n_groups(cfg)):
        for pos, desc in enumerate(pattern):
            lp = jax.tree.map(lambda t: t[g], params["groups"][pos])
            h = layers.apply_norm(cfg, lp["ln1"], x)
            a, _ = layers.attention(cfg, lp["attn"], h, positions=positions)
            x = x + a
            h2 = layers.apply_norm(cfg, lp["ln2"], x)
            x = x + layers.apply_mlp(cfg, lp["mlp"], h2)
            feats.append(jnp.mean(x.astype(jnp.float32), axis=(1, 2)))
    return jnp.stack(feats, axis=1)  # (B, L)


def main():
    cfg = get_arch("qwen3-1.7b", smoke=True).replace(n_layers=6)
    params = model_lib.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (512, 16)), jnp.int32
    )
    feats = np.array(collect_layer_features(cfg, params, tokens))
    feats += rng.laplace(size=feats.shape) * 0.05 * feats.std()  # break ties

    model = DirectLiNGAM(backend="blocked").fit(feats)
    order = model.causal_order_
    depth_corr = np.corrcoef(np.argsort(order), np.arange(len(order)))[0, 1]
    print("layer causal order:", order)
    print(f"correlation with depth: {depth_corr:.2f}")
    print(
        "note: with random (untrained) weights the layer features are a\n"
        "near-deterministic chain plus injected measurement noise — outside\n"
        "LiNGAM's independent-structural-noise assumptions — so the order\n"
        "is exploratory here; the point of this example is the integration\n"
        "path (LM activations -> DirectLiNGAM), not a causal claim."
    )


if __name__ == "__main__":
    main()
