"""Quickstart: causal discovery with AcceleratedLiNGAM on TPU/CPU.

    PYTHONPATH=src python examples/quickstart.py [--telemetry] [--profile]

Simulates data from a known layered DAG (paper §3.1 protocol), runs the
parallel DirectLiNGAM, verifies it against the sequential reference,
prints the recovered adjacency — then *uses* the graph: total-effect
queries, a do-intervention, and root-cause attribution of an anomalous
sample (the full discovery -> query path).

With ``--telemetry`` the run also drives the serving engine (a fit
micro-batch, a streaming session through refit flushes, and a causal
query) with the observability layer on (:mod:`repro.obs`), then prints
the span tree, the metrics snapshot, and the compile-event log —
covering kernel dispatch -> ordering -> pruning -> serve flush ->
query.

With ``--profile`` it runs the performance-accounting layer
(:mod:`repro.obs.profile`): a profiled fit inside a correlated
host+device trace window, the stage-attribution table (seconds, FLOPs,
%-of-roofline per stage and kernel variant), and the captured cost
records — writing the device trace (Perfetto) next to the host span
trace under the ``--profile-out`` directory.
"""

import argparse

import numpy as np

from repro.baselines.sequential_lingam import causal_order_sequential
from repro.core import DirectLiNGAM, VarLiNGAM, api, batched
from repro.core.bootstrap import bootstrap_lingam
from repro.data.simulate import simulate_do, simulate_lingam, simulate_var_stocks
from repro.infer import effects, intervene, rca


def main():
    print("=== DirectLiNGAM (paper Algorithm 1, parallel) ===")
    gt = simulate_lingam(m=5_000, d=10, seed=0)
    model = DirectLiNGAM(backend="blocked", prune_threshold=0.1).fit(gt.data)
    print("causal order :", model.causal_order_)
    print("sequential   :", causal_order_sequential(gt.data))
    agree = np.array_equal(
        model.causal_order_, causal_order_sequential(gt.data)
    )
    print(f"parallel == sequential: {agree}")

    est = np.abs(model.adjacency_) > 0.1
    true = gt.adjacency != 0
    print(f"edges: true={true.sum()} recovered={est.sum()} "
          f"correct={np.sum(est & true)}")

    print("\n=== Pallas kernel backend (interpret mode on CPU) ===")
    model_k = DirectLiNGAM(backend="pallas", interpret=True).fit(gt.data)
    print("pallas order :", model_k.causal_order_)
    print("orders agree :", np.array_equal(model.causal_order_,
                                           model_k.causal_order_))

    print("\n=== Functional core: pure fit_fn + vmap-batched bootstrap ===")
    import jax.numpy as jnp

    res = api.fit_fn(jnp.asarray(gt.data), api.FitConfig(backend="blocked"))
    print("fit_fn order  :", np.asarray(res.order))
    print("resid_var[:4] :", np.asarray(res.resid_var)[:4].round(3))

    boot = bootstrap_lingam(
        gt.data, n_sampling=10, threshold=0.1, seed=0, strategy="vmap"
    )
    print("stable edges (P>=0.8):",
          [(i, j, p) for i, j, p, _ in boot.stable_edges(0.8)][:5])

    # fit_many: one compiled program fitting an ensemble of datasets.
    xs = jnp.stack([
        jnp.asarray(simulate_lingam(m=2_000, d=10, seed=s).data)
        for s in range(4)
    ])
    ens = batched.fit_many(xs, api.FitConfig(compaction="staged"))
    print("fit_many orders (4 datasets):")
    print(np.asarray(ens.order))

    print("\n=== VarLiNGAM (paper §3.2) ===")
    x, b0, m1 = simulate_var_stocks(m=2_000, d=20, edge_prob=0.1, seed=1)
    var_model = VarLiNGAM(lags=1, prune_threshold=0.05).fit(x)
    th0 = var_model.adjacency_matrices_[0]
    tp = np.sum((np.abs(th0) > 0.05) & (b0 != 0))
    print(f"instantaneous edges: true={np.sum(b0 != 0)} "
          f"recovered-correct={tp}")

    print("\n=== Causal queries on the fitted graph (repro.infer) ===")
    # Total effects: (I - B)^-1 by triangular solve in causal order.
    t = np.asarray(effects.total_effects(model.result_))
    off = np.abs(t) * (1 - np.eye(t.shape[0]))
    i, j = np.unravel_index(np.argmax(off), t.shape)
    print(f"strongest total effect: x{j} -> x{i} = {t[i, j]:+.3f} "
          f"(direct {model.adjacency_[i, j]:+.3f})")

    # Intervention: predicted do(x_j = +2) mean vs interventional sampling.
    mu_do, _ = intervene.interventional_moments(
        model.result_, {int(j): 2.0},
        mean=gt.data.mean(axis=0), cov=np.cov(gt.data.T, ddof=0),
    )
    mc = simulate_do(gt.adjacency, {int(j): 2.0}, m=20_000, seed=0)
    print(f"do(x{j}=2): predicted E[x{i}]={mu_do[i]:+.3f}  "
          f"Monte-Carlo={mc[:, i].mean():+.3f}")

    # Root-cause attribution: inject an anomaly into x_j's noise term
    # and ask the graph who broke.
    x_anom = gt.data[:1].copy()
    x_anom[0] += 4.0 * t[:, j]  # shift j's noise by +4, propagated
    report = rca.attribute(
        model.result_, x_anom, mean=gt.data.mean(axis=0), target=int(i)
    )
    print(f"RCA: implicated root = x{report.root[0]} (injected x{j}); "
          f"ranking {report.ranking(top_k=3)}")


def telemetry_demo(out_dir=None):
    """Drive dispatch -> ordering -> pruning -> serve flush -> query
    with telemetry on; print the span tree + metrics + compile log.

    ``out_dir`` additionally writes the run's artifacts to disk:
    ``trace_events.json`` (Chrome/Perfetto trace-event format — open in
    ``chrome://tracing`` or https://ui.perfetto.dev) and
    ``metrics_snapshot.json``.
    """
    import json
    import os

    from repro import obs
    from repro.infer import query as query_lib
    from repro.serve.engine import CausalDiscoveryEngine, FitRequest
    from repro.stream.session import StreamConfig

    obs.enable()
    obs.reset_all()
    rng = np.random.default_rng(0)

    print("\n=== Telemetry: serving engine under observation ===")
    eng = CausalDiscoveryEngine(batch_size=4)
    eng.run([
        FitRequest(data=rng.normal(size=(256, 8)).astype(np.float32))
        for _ in range(3)
    ])
    sid = eng.open_stream(
        StreamConfig(d=6, chunk=32, window_chunks=4, refit_every=1)
    )
    for _ in range(7):
        eng.post_chunk(sid, rng.normal(size=(32, 6)).astype(np.float32))
    eng.flush_streams()
    answered = eng.query([
        query_lib.EffectQuery(graph=sid),
        query_lib.InterventionQuery(graph=sid, do={0: 1.5}),
    ])
    print(f"stream {sid}: {eng.stream_session(sid).n_refits} refits, "
          f"{len(answered)} queries answered, "
          f"{len(eng.last_flush_errors)} flush errors")

    print("\n--- span tree (spans tagged [trace] ran at trace time) ---")
    print(obs.format_tree())
    print("--- metrics snapshot ---")
    print(json.dumps(obs.metrics.snapshot(), indent=1, sort_keys=True))
    print("--- compile events (op -> compiles) ---")
    for op, n in sorted(obs.compile_log.by_op().items()):
        print(f"  {op}: {n}")

    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        trace_path = obs.write_chrome_trace(
            os.path.join(out_dir, "trace_events.json")
        )
        metrics_path = os.path.join(out_dir, "metrics_snapshot.json")
        with open(metrics_path, "w") as f:
            json.dump(obs.metrics.snapshot(), f, indent=1, sort_keys=True)
        print(f"wrote {trace_path} (open in chrome://tracing or "
              f"ui.perfetto.dev) and {metrics_path}")


def profile_demo(out_dir=None):
    """Profiled fit + stage attribution + correlated device trace.

    ``out_dir`` receives ``trace_events.json`` (host spans, Chrome
    trace-event format), a ``device_trace/`` directory (the
    ``jax.profiler`` Perfetto/XPlane timeline with host span names
    mirrored as TraceAnnotations), and ``profile_snapshot.json`` (the
    captured cost records + device peaks).
    """
    import json
    import os

    from repro import obs
    from repro.analysis import report
    from repro.obs import profile

    obs.enable()
    profile.enable()
    obs.reset_all()

    print("\n=== Profiling: cost capture + roofline attribution ===")
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        with profile.device_trace(os.path.join(out_dir, "device_trace")):
            payload = report.live_attribution(m=512, d=16, repeats=2)
    else:
        payload = report.live_attribution(m=512, d=16, repeats=2)
    print(report.render(payload))

    print("\n--- captured cost records ---")
    for rec in profile.records():
        print(f"  {rec.op} shape={rec.shape} flops={rec.flops:.3g} "
              f"bytes={rec.bytes_accessed:.3g} temp={rec.temp_bytes} "
              f"calls={rec.calls} best={rec.best_s * 1e3:.2f}ms")

    if out_dir is not None:
        trace_path = obs.write_chrome_trace(
            os.path.join(out_dir, "trace_events.json")
        )
        snap_path = os.path.join(out_dir, "profile_snapshot.json")
        with open(snap_path, "w") as f:
            json.dump(profile.snapshot(), f, indent=1)
        print(f"\nwrote {trace_path}, {snap_path}, and "
              f"{os.path.join(out_dir, 'device_trace')}/ "
              f"(open both traces in ui.perfetto.dev to correlate "
              f"host spans with the device timeline)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--telemetry", action="store_true",
                    help="run the serving/streaming demo with repro.obs "
                         "enabled and print span tree + metrics")
    ap.add_argument("--telemetry-out", type=str, default="telemetry_out",
                    help="directory for --telemetry artifacts "
                         "(chrome trace + metrics snapshot)")
    ap.add_argument("--profile", action="store_true",
                    help="run the profiled fit: stage-attribution table, "
                         "cost records, correlated host+device trace")
    ap.add_argument("--profile-out", type=str, default="profile_out",
                    help="directory for --profile artifacts "
                         "(host trace, device trace, cost snapshot)")
    args = ap.parse_args()
    main()
    if args.telemetry:
        telemetry_demo(out_dir=args.telemetry_out)
    if args.profile:
        profile_demo(out_dir=args.profile_out)
