"""Quickstart: causal discovery with AcceleratedLiNGAM on TPU/CPU.

    PYTHONPATH=src python examples/quickstart.py

Simulates data from a known layered DAG (paper §3.1 protocol), runs the
parallel DirectLiNGAM, verifies it against the sequential reference, and
prints the recovered adjacency.
"""

import numpy as np

from repro.baselines.sequential_lingam import causal_order_sequential
from repro.core import DirectLiNGAM, VarLiNGAM
from repro.data.simulate import simulate_lingam, simulate_var_stocks


def main():
    print("=== DirectLiNGAM (paper Algorithm 1, parallel) ===")
    gt = simulate_lingam(m=5_000, d=10, seed=0)
    model = DirectLiNGAM(backend="blocked", prune_threshold=0.1).fit(gt.data)
    print("causal order :", model.causal_order_)
    print("sequential   :", causal_order_sequential(gt.data))
    agree = np.array_equal(
        model.causal_order_, causal_order_sequential(gt.data)
    )
    print(f"parallel == sequential: {agree}")

    est = np.abs(model.adjacency_) > 0.1
    true = gt.adjacency != 0
    print(f"edges: true={true.sum()} recovered={est.sum()} "
          f"correct={np.sum(est & true)}")

    print("\n=== Pallas kernel backend (interpret mode on CPU) ===")
    model_k = DirectLiNGAM(backend="pallas", interpret=True).fit(gt.data)
    print("pallas order :", model_k.causal_order_)
    print("orders agree :", np.array_equal(model.causal_order_,
                                           model_k.causal_order_))

    print("\n=== VarLiNGAM (paper §3.2) ===")
    x, b0, m1 = simulate_var_stocks(m=2_000, d=20, edge_prob=0.1, seed=1)
    var_model = VarLiNGAM(lags=1, prune_threshold=0.05).fit(x)
    th0 = var_model.adjacency_matrices_[0]
    tp = np.sum((np.abs(th0) > 0.05) & (b0 != 0))
    print(f"instantaneous edges: true={np.sum(b0 != 0)} "
          f"recovered-correct={tp}")


if __name__ == "__main__":
    main()
