"""End-to-end training driver: train a ~100M-param LM with the full
substrate (data pipeline -> AdamW -> fault-tolerant trainer -> checkpoint),
then serve it with batched requests.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch olmoe-1b-7b --smoke

The default config is a ~100M dense transformer (qwen3-family wiring).
Interrupt with Ctrl-C: the trainer writes an emergency checkpoint; rerun
the same command and it resumes exactly where it stopped.
"""

import argparse
import logging

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, get_arch
from repro.serve.engine import Request, ServeEngine
from repro.train.optimizer import AdamW, cosine_warmup
from repro.train.trainer import Trainer

logging.basicConfig(level=logging.INFO, format="%(message)s")

# ~100M params: 12L x d512 x ff2048, vocab 16384 -> 12*(4*512^2+3*512*2048)
# + 2*16384*512 = ~70M wired like qwen3 (GQA + qk-norm).
LM_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=16384,
    head_dim=64,
    qk_norm=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, 20 steps")
    args = ap.parse_args()

    if args.arch == "lm-100m":
        cfg = LM_100M
    else:
        cfg = get_arch(args.arch, smoke=True)
    steps = 20 if args.smoke else args.steps
    if args.smoke:
        cfg = cfg.replace(n_layers=2, d_model=64, d_ff=128, vocab_size=512,
                          n_heads=4, n_kv_heads=2, head_dim=16)

    shape = ShapeConfig("train", "train", args.seq, args.batch)
    opt = AdamW(
        lr=cosine_warmup(3e-4, warmup=max(steps // 20, 1), total=steps),
        weight_decay=0.1,
        state_dtype=cfg.optimizer_dtype,
    )
    trainer = Trainer(cfg, shape, optimizer=opt, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(steps // 5, 10))
    state, step, losses = trainer.train(n_steps=steps, log_every=10)
    print(f"\ntrained to step {step}; loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("\n=== serving the trained model ===")
    engine = ServeEngine(cfg, state.params, batch_size=2,
                         max_seq=args.seq + 32)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=16)
        for _ in range(2)
    ]
    engine.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"request {i}: generated {r.out_tokens}")


if __name__ == "__main__":
    main()
