"""Causal discovery on stock-like time series (paper §4.2, Fig. 4/Table 2).

    PYTHONPATH=src python examples/stock_varlingam.py [--full]

VAR(1) + instantaneous LiNGAM graph on synthetic S&P-like hourly series
(d=487 with --full). Prints degree-distribution stats and the top-5
exerting / receiving indices by total causal effect.
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="d=487 (paper scale)")
    args = ap.parse_args()
    from benchmarks.bench_stocks import run

    res = run(quick=not args.full)
    print("\nTop exerting nodes :", res["top_exerting"])
    print("Top receiving nodes:", res["top_receiving"])
    print("Leaf (holding-co-like) nodes:", res["leaf_nodes"])


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
