"""Causal discovery on stock-like time series (paper §4.2, Fig. 4/Table 2).

    PYTHONPATH=src python examples/stock_varlingam.py [--full]
    PYTHONPATH=src python examples/stock_varlingam.py --stream [--full]

Default mode: VAR(1) + instantaneous LiNGAM graph on synthetic S&P-like
hourly series (d=487 with --full). Prints degree-distribution stats and
the top-5 exerting / receiving indices by total causal effect.

``--stream`` mode: slides a chunked rolling window over the same panel
with the streaming subsystem (incremental moment store + rolling
VarLiNGAM) and prints per-slide graph-delta stats — edges added/removed,
magnitude of change, and the per-slide wall time.
"""

import argparse
import time


def run_stream(full: bool) -> None:
    import numpy as np

    from repro.core import api
    from repro.data.simulate import simulate_var_stocks
    from repro.stream import RollingVarLiNGAM, graph_delta

    d, chunk, window_chunks, n_slides = (
        (487, 256, 8, 2) if full else (32, 128, 4, 4)
    )
    lags = 1
    config = api.FitConfig(
        backend="blocked", compaction="staged", moment_chunk=chunk
    )
    n_chunks = window_chunks + n_slides
    x, _, _ = simulate_var_stocks(m=chunk * n_chunks + 8, d=d, seed=0)

    roll = RollingVarLiNGAM(
        d, chunk, window_chunks, lags=lags, config=config
    )
    prev = None
    print(
        f"streaming d={d}, chunk={chunk}, "
        f"window={window_chunks * chunk} rows, {n_slides} slides"
    )
    for k in range(n_chunks):
        roll.push(x[k * chunk:(k + 1) * chunk])
        if not roll.ready:
            continue
        t0 = time.time()
        fit = roll.refit()
        dt = time.time() - t0
        b0 = np.asarray(fit.result.adjacency)
        delta = graph_delta(prev, b0, 0.05, roll.n_pushed - window_chunks)
        prev = b0
        print(f"  {delta.summary()}  [{dt:.3f}s]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="d=487 (paper scale)")
    ap.add_argument(
        "--stream", action="store_true",
        help="rolling-window streaming mode (per-slide graph deltas)",
    )
    args = ap.parse_args()
    if args.stream:
        run_stream(args.full)
        return
    from benchmarks.bench_stocks import run

    res = run(quick=not args.full)
    print("\nTop exerting nodes :", res["top_exerting"])
    print("Top receiving nodes:", res["top_receiving"])
    print("Leaf (holding-co-like) nodes:", res["leaf_nodes"])


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
