"""Causal discovery on stock-like time series (paper §4.2, Fig. 4/Table 2).

    PYTHONPATH=src python examples/stock_varlingam.py [--full]
    PYTHONPATH=src python examples/stock_varlingam.py --stream [--full]

Default mode: VAR(1) + instantaneous LiNGAM graph on synthetic S&P-like
hourly series (d=487 with --full). Prints degree-distribution stats and
the top-5 exerting / receiving indices by total causal effect.

``--stream`` mode: slides a chunked rolling window over the same panel
with the streaming subsystem (incremental moment store + rolling
VarLiNGAM) and prints per-slide graph-delta stats — edges added/removed,
magnitude of change, and the per-slide wall time.

``--drift`` mode: a regime change mid-stream. A monitored session
(:mod:`repro.stream.monitor`) coasts through the stationary stretch
(refit cadence doubling while the drift score stays low), then a
structural break — the strongest instantaneous edge rewired — fires
drift alerts that force an immediate refit and name the broken variable
with its candidate root causes.

Both modes end by *querying* the fitted graph (``repro.infer``): the
strongest total instantaneous effects, a lag-propagated impulse
response, and root-cause attribution of the most anomalous recent
sample — the full discovery -> query path.
"""

import argparse
import time


def query_fitted_graph(result, var_coefs, rows, mean) -> None:
    """Effect + IRF + RCA queries against one fitted graph."""
    import numpy as np

    from repro.infer import effects, rca

    t = np.asarray(effects.total_effects(result))
    off = np.abs(t) * (1 - np.eye(t.shape[0]))
    i, j = np.unravel_index(np.argmax(off), t.shape)
    print(f"strongest total effect: x{j} -> x{i} = {t[i, j]:+.3f}")

    irf = np.asarray(effects.var_irf(
        result.adjacency, result.order, var_coefs, horizon=3
    ))
    print("shock persistence |IRF_h| (mean abs response to a unit "
          "shock):", [round(float(np.abs(h).mean()), 4) for h in irf])

    report = rca.attribute(result, rows, mean=mean)
    worst = int(np.argmax(np.abs(report.scores).max(axis=1)))
    print(f"RCA over {rows.shape[0]} recent samples: most anomalous "
          f"sample {worst}, implicated root x{report.root[worst]}, "
          f"ranking {report.ranking(row=worst, top_k=3)}")


def run_stream(full: bool) -> None:
    import numpy as np

    from repro.core import api
    from repro.data.simulate import simulate_var_stocks
    from repro.stream import RollingVarLiNGAM, graph_delta

    d, chunk, window_chunks, n_slides = (
        (487, 256, 8, 2) if full else (32, 128, 4, 4)
    )
    lags = 1
    config = api.FitConfig(
        backend="blocked", compaction="staged", moment_chunk=chunk
    )
    n_chunks = window_chunks + n_slides
    x, _, _ = simulate_var_stocks(m=chunk * n_chunks + 8, d=d, seed=0)

    roll = RollingVarLiNGAM(
        d, chunk, window_chunks, lags=lags, config=config
    )
    prev = None
    print(
        f"streaming d={d}, chunk={chunk}, "
        f"window={window_chunks * chunk} rows, {n_slides} slides"
    )
    fit = None
    for k in range(n_chunks):
        roll.push(x[k * chunk:(k + 1) * chunk])
        if not roll.ready:
            continue
        t0 = time.time()
        fit = roll.refit()
        dt = time.time() - t0
        b0 = np.asarray(fit.result.adjacency)
        delta = graph_delta(prev, b0, 0.05, roll.n_pushed - window_chunks)
        prev = b0
        print(f"  {delta.summary()}  [{dt:.3f}s]")

    # End of stream: query the final rolling estimate — effects, lag
    # propagation, and RCA of the freshest chunk (window-mean baseline
    # straight from the incremental moment store, no row re-reads).
    print("\n=== querying the final rolling graph ===")
    win_mean = np.asarray(roll.aug_state.mean)[:d]
    query_fitted_graph(
        fit.result, fit.var_coefs,
        x[(n_chunks - 1) * chunk:n_chunks * chunk][:16], win_mean,
    )


def run_drift(full: bool) -> None:
    """Regime-change demo: monitored session across a structural break."""
    import numpy as np

    from repro.data.simulate import simulate_var_breaks
    from repro.serve.engine import CausalDiscoveryEngine
    from repro.stream import MonitorConfig, StreamConfig

    d, chunk, window_chunks = (64, 200, 8) if full else (16, 100, 8)
    m = 6000 if not full else 10_000
    br = simulate_var_breaks(m=m, d=d, kind="edge_flip", seed=3, at=m // 2)
    print(
        f"regime change at row {br.at}: edge into x{br.variable} rewired "
        f"(d={d}, chunk={chunk}, window={window_chunks * chunk} rows)"
    )

    eng = CausalDiscoveryEngine(batch_size=1)
    sid = eng.open_stream(StreamConfig(
        d=d, chunk=chunk, window_chunks=window_chunks,
        refit_every=2, coast_max=32, monitor=MonitorConfig(),
    ))
    session = eng.stream_session(sid)
    break_chunk = br.at // chunk
    for ci, start in enumerate(range(0, (m // chunk) * chunk, chunk)):
        deltas = eng.post_chunk(sid, br.series[start:start + chunk])
        for _, delta in deltas:
            mark = " <-- post-break" if ci >= break_chunk else ""
            print(f"  chunk {ci:3d} cadence={session.cadence:2d} "
                  f"{delta.summary()}{mark}")
        for alert in eng.poll_alerts(sid):
            print(f"  chunk {ci:3d} ALERT {alert.summary()}")
    eng.flush_streams()
    hist = list(session.alert_history)
    detected = [a for a in hist if a.chunk_index > break_chunk]
    print(
        f"\n{len(hist)} alerts total; first post-break detection "
        + (f"{detected[0].chunk_index - break_chunk} chunk(s) after the "
           f"break, implicating x{detected[0].variable} "
           f"({detected[0].kind})" if detected else "never")
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="d=487 (paper scale)")
    ap.add_argument(
        "--stream", action="store_true",
        help="rolling-window streaming mode (per-slide graph deltas)",
    )
    ap.add_argument(
        "--drift", action="store_true",
        help="regime-change demo: drift alerts + adaptive refit cadence",
    )
    args = ap.parse_args()
    if args.drift:
        run_drift(args.full)
        return
    if args.stream:
        run_stream(args.full)
        return
    from benchmarks.bench_stocks import run

    res = run(quick=not args.full)
    print("\nTop exerting nodes :", res["top_exerting"])
    print("Top receiving nodes:", res["top_receiving"])
    print("Leaf (holding-co-like) nodes:", res["leaf_nodes"])

    # Discovery done — now query the graph: refit a compact panel and
    # ask it for effects, shock propagation, and root causes.
    import numpy as np

    from repro.core import VarLiNGAM
    from repro.data.simulate import simulate_var_stocks

    print("\n=== querying a fitted VarLiNGAM graph ===")
    d = 487 if args.full else 32
    x, _, _ = simulate_var_stocks(m=1500, d=d, edge_prob=0.05, seed=0)
    model = VarLiNGAM(lags=1, prune_threshold=0.05).fit(x)
    query_fitted_graph(
        model.result_, model.var_coefs_, x[-16:], x.mean(axis=0)
    )


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
