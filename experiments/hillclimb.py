import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing driver — hypothesis -> change -> measure -> validate.

Cells (chosen from the §Roofline baseline table):
  A. qwen2-1.5b / prefill_32k   — worst meaningful roofline fraction (2.1%),
     memory-bound on attention-score materialization; attention cannot TP
     (12 heads vs 16-way axis).
  B. jamba-v0.1-52b / decode_32k — most collective-bound cell
     (collective/bound ratio ~300x): FSDP all-gathers at decode.
  C. lingam-1m-2048 / ordering   — the paper's own technique at scale,
     compute-bound.
  D. olmoe-1b-7b / train_4k      — bonus: EP all-to-all bound MoE training.

Each variant records: hypothesis, predicted delta, analytic before/after,
HLO evidence (re-lower + collective parse) where the change is code-level,
and verdict. Output: experiments/hillclimb.md (+ .json).

  PYTHONPATH=src python experiments/hillclimb.py
"""

import json  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

from repro.analysis import roofline  # noqa: E402
from repro.analysis.analytic_cost import analytic_collectives, cell_cost  # noqa: E402
from repro.configs.base import SHAPES, get_arch  # noqa: E402

RESULTS = []
LINES = ["# §Perf hillclimb log", ""]


def emit(s=""):
    LINES.append(s)
    print(s)


def lm_terms(arch, shape_name, *, cfg_overrides=None, flash=False,
             seq_shard_kv=False, moe_impl="scatter", grad_bytes=4):
    cfg = get_arch(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    cost = cell_cost(cfg, shape, n_model=16, n_batch_shards=16,
                     moe_impl=moe_impl, flash_attention=flash,
                     seq_shard_kv=seq_shard_kv)
    coll = analytic_collectives(cfg, shape, n_model=16, n_batch_shards=16,
                                grad_dtype_bytes=grad_bytes)
    coll_dev = sum(coll.values())
    t = roofline.roofline_terms(cost["flops_per_dev"],
                                cost["bytes_per_dev"], coll_dev)
    return t, cost, coll


def hlo_evidence(arch, shape_name, **kw):
    """Re-lower + compile the cell, parse collectives (structure proof)."""
    import jax  # noqa: F401

    from repro.launch.dryrun import lower_lm_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with mesh:
        lowered, aux = lower_lm_cell(arch, shape_name, mesh, **kw)
    compiled = lowered.compile()
    coll = roofline.collective_bytes(compiled.as_text())
    return {
        "compile_s": round(time.time() - t0, 1),
        "hlo_collectives": coll,
        "hlo_flops_per_dev": compiled.cost_analysis().get("flops", None)
        if compiled.cost_analysis() else None,
    }


def fmt(t):
    def s(x):
        return f"{x*1e3:.2f}ms" if x < 1 else f"{x:.2f}s"

    return (f"compute={s(t['compute_s'])} memory={s(t['memory_s'])} "
            f"collective={s(t['collective_s'])} -> bound={s(t['bound_s'])} "
            f"({t['dominant']})")


def record(cell, variant, hypothesis, before, after, verdict, extra=None):
    RESULTS.append({
        "cell": cell, "variant": variant, "hypothesis": hypothesis,
        "before": before, "after": after, "verdict": verdict,
        "extra": extra or {},
    })


# ======================================================================
# Cell A: qwen2-1.5b prefill_32k
# ======================================================================
def cell_a():
    emit("## Cell A — qwen2-1.5b / prefill_32k (memory-bound)")
    base_t, base_c, _ = lm_terms("qwen2-1.5b", "prefill_32k")
    emit(f"- baseline: {fmt(base_t)}")
    emit(f"  - bytes components (per-dev scaled): attention scores "
         f"{base_c['bytes_components']['attn']/16/1e12:.2f} TB of "
         f"{base_c['bytes_per_dev']/1e12:.2f} TB total")

    emit("")
    emit("**A1 — flash-style chunked attention** (`attn_impl=chunked`, "
         "implemented in models/layers.py `_sdpa_chunked`)")
    emit("- Hypothesis: the (B,H,S,S) score materialization is "
         f"2*32*12*32768^2*2B = {2*32*12*32768**2*2/1e12:.1f} TB global — "
         "dominating memory; streaming KV chunks with running max/sum "
         "removes it entirely. Predicted: memory 3.5s -> ~0.2s; bound "
         "flips to compute.")
    a1_t, _, _ = lm_terms("qwen2-1.5b", "prefill_32k", flash=True,
                          cfg_overrides={"attn_impl": "chunked"})
    emit(f"- after: {fmt(a1_t)}")
    v = "CONFIRMED" if a1_t["dominant"] == "compute" and \
        a1_t["memory_s"] < 0.3 * base_t["memory_s"] else "REFUTED"
    emit(f"- verdict: {v}")
    record("A", "A1-chunked-attn", "score matmul bytes dominate", fmt(base_t),
           fmt(a1_t), v)

    emit("")
    emit("**A2 — pad attention heads 12 -> 16** (same trick as vocab/expert "
         "padding: 4 zero-output heads make H divisible by the model axis)")
    emit("- Hypothesis: with 12 heads attention cannot TP-shard, so per-"
         "device attention FLOPs divide only by 16 batch shards; padding "
         "to 16 heads costs +33% global attention FLOPs but divides by "
         "256 — net ~12x lower per-device attention compute. Predicted: "
         "compute ~2.0s -> ~0.25s; bound flips to collective (~0.23s).")
    a2_t, a2_c, _ = lm_terms(
        "qwen2-1.5b", "prefill_32k", flash=True,
        cfg_overrides={"attn_impl": "chunked", "n_heads": 16},
    )
    emit(f"- after: {fmt(a2_t)}")
    gain = base_t["bound_s"] / a2_t["bound_s"]
    v = "CONFIRMED" if gain > 8 else "PARTIAL"
    emit(f"- verdict: {v} — cumulative bound {base_t['bound_s']:.2f}s -> "
         f"{a2_t['bound_s']*1e3:.0f}ms ({gain:.1f}x)")
    record("A", "A2-pad-heads", "12 heads block TP", fmt(a1_t), fmt(a2_t), v)

    emit("")
    emit("**A2 HLO evidence** (re-lower + compile both variants):")
    ev_base = hlo_evidence("qwen2-1.5b", "prefill_32k")
    ev_a2 = hlo_evidence(
        "qwen2-1.5b", "prefill_32k",
        cfg_overrides={"attn_impl": "chunked", "n_heads": 16},
    )
    emit(f"- baseline compile {ev_base['compile_s']}s, collectives "
         f"{ev_base['hlo_collectives']}")
    emit(f"- A1+A2  compile {ev_a2['compile_s']}s, collectives "
         f"{ev_a2['hlo_collectives']}")
    record("A", "A2-hlo", "", "", "", "", {"base": ev_base, "a2": ev_a2})
    return base_t, a2_t


# ======================================================================
# Cell B: jamba decode_32k
# ======================================================================
def cell_b():
    emit("")
    emit("## Cell B — jamba-v0.1-52b / decode_32k (collective-bound)")
    base_t, base_c, base_coll = lm_terms("jamba-v0.1-52b", "decode_32k")
    emit(f"- baseline: {fmt(base_t)}")
    emit(f"  - collective components: { {k: f'{v/1e9:.2f}GB' for k, v in base_coll.items()} }")

    emit("")
    emit("**B1 — serve-mode sharding: disable FSDP at decode** "
         "(`fsdp=False`; training keeps ZeRO-3, serving is weight-"
         "stationary TP)")
    emit("- Hypothesis: the 257ms collective term is per-step parameter "
         "all-gather (52B params / 16 model shards, bf16 ~ 13GB/dev-step) "
         "— pure waste at decode where params never change. Predicted: "
         "collective -> sub-ms, bound flips to memory (~6ms, KV-cache "
         "reads at kv=8 heads unshardable on the 16-way axis).")
    b1_t, b1_c, b1_coll = lm_terms("jamba-v0.1-52b", "decode_32k",
                                   cfg_overrides={"fsdp": False})
    emit(f"- after: {fmt(b1_t)}")
    v = "CONFIRMED" if b1_t["dominant"] == "memory" and \
        b1_t["bound_s"] < 0.05 * base_t["bound_s"] else "REFUTED"
    emit(f"- verdict: {v} ({base_t['bound_s']*1e3:.0f}ms -> "
         f"{b1_t['bound_s']*1e3:.2f}ms)")
    record("B", "B1-no-fsdp-serve", "FSDP gathers at decode are waste",
           fmt(base_t), fmt(b1_t), v)

    emit("")
    emit("**B2 — sequence-sharded KV cache** (`seq_shard_kv=True` in "
         "dist/sharding.py: kv=8 < 16-way axis, so shard the 32k cache "
         "sequence over `model`; softmax partials psum)")
    emit("- Hypothesis: after B1 the bound is KV-cache reads "
         "(2*128*32768*8*128*2B x 4 attn layers / 16 batch shards = "
         "4.3GB/dev-step); sharding the sequence 16-way cuts it to "
         "0.27GB + tiny softmax-partial psums. Predicted bound ~1ms.")
    b2_t, b2_c, _ = lm_terms("jamba-v0.1-52b", "decode_32k",
                             cfg_overrides={"fsdp": False},
                             seq_shard_kv=True)
    emit(f"- after: {fmt(b2_t)}")
    gain = base_t["bound_s"] / b2_t["bound_s"]
    v = "CONFIRMED" if b2_t["bound_s"] < 0.4 * b1_t["bound_s"] else "PARTIAL"
    emit(f"- verdict: {v} — cumulative {base_t['bound_s']*1e3:.0f}ms -> "
         f"{b2_t['bound_s']*1e3:.2f}ms ({gain:.0f}x)")
    record("B", "B2-seq-shard-kv", "KV reads bound after B1", fmt(b1_t),
           fmt(b2_t), v)

    emit("")
    emit("**B HLO evidence:**")
    ev_base = hlo_evidence("jamba-v0.1-52b", "decode_32k")
    ev_b2 = hlo_evidence("jamba-v0.1-52b", "decode_32k",
                         cfg_overrides={"fsdp": False}, seq_shard_kv=True)
    emit(f"- baseline compile {ev_base['compile_s']}s, collectives "
         f"{ev_base['hlo_collectives']}")
    emit(f"- B1+B2  compile {ev_b2['compile_s']}s, collectives "
         f"{ev_b2['hlo_collectives']}")
    all_gather_drop = (
        ev_base["hlo_collectives"]["all-gather"]
        - ev_b2["hlo_collectives"]["all-gather"]
    )
    emit(f"- all-gather bytes drop in partitioned HLO: "
         f"{all_gather_drop/1e6:.1f} MB (per while-iteration; x n_groups "
         f"at runtime)")
    record("B", "B-hlo", "", "", "", "", {"base": ev_base, "b2": ev_b2})
    return base_t, b2_t


# ======================================================================
# Cell C: lingam-1m-2048 (the paper's technique)
# ======================================================================
def _lingam_terms(m, d, *, staged=False, passes=3, elem_bytes=4,
                  nm=16, nb=16, chips=256, flops_per_pair=30.0):
    """Numeric roofline for the sharded ordering under variants."""
    stages = []
    if staged:
        d_s = d
        while d_s > 64:
            stages.append((d_s, d_s - d_s // 2))
            d_s = d_s // 2
        stages.append((d_s, d_s))
    else:
        stages = [(d, d)]
    fl = by = co = 0.0
    m_loc = m / nb
    for d_s, steps in stages:
        tile = d_s / nm
        fl += steps * (2.0 * m * d_s * d_s / chips
                       + flops_per_pair * m_loc * tile * d_s)
        by += steps * (passes * m_loc * d_s * elem_bytes)
        co += steps * (d_s * d_s * 4.0 * (1.0 + 2.0 / nm + 2.0))
    t = roofline.roofline_terms(fl, by, co)
    return t, fl, by, co


def cell_c():
    emit("")
    emit("## Cell C — lingam-1m-2048 / ordering (the paper's technique, "
         "compute-bound)")
    base_t, base_fl, base_by, _ = _lingam_terms(1_000_000, 2048)
    emit(f"- baseline: {fmt(base_t)}")

    emit("")
    emit("**C1 — active-set compaction** (`causal_order_compact`: shrink "
         "the physical problem on a static stage schedule; exact — tests "
         "prove identical order)")
    emit("- Hypothesis: the masked fixed-shape scan pays full d^2*m pair "
         "work all d steps (~m*d^3 total) although the sequential "
         "algorithm's U-set shrinks; compacting at powers of two cuts "
         "total pair work to sum(d_s^2 * d_s/2) = (4/7) m*d^3. "
         "Predicted: compute 5.1s -> ~2.9s; memory also shrinks (slab "
         "narrows) -> memory-bound next.")
    c1_t, c1_fl, _, _ = _lingam_terms(1_000_000, 2048, staged=True)
    emit(f"- after: {fmt(c1_t)} (flops x{c1_fl/base_fl:.3f})")
    v = "CONFIRMED" if 0.5 < c1_fl / base_fl < 0.62 else "PARTIAL"
    emit(f"- verdict: {v}")
    record("C", "C1-staged", "masked scan wastes inactive pairs",
           fmt(base_t), fmt(c1_t), v)

    emit("")
    emit("**C1 wall-clock validation (CPU, reduced d=96, m=20000):**")
    import jax.numpy as jnp

    from repro.core.ordering import causal_order, causal_order_compact
    from repro.data.simulate import simulate_lingam

    gt = simulate_lingam(m=20_000, d=96, seed=0)
    x = jnp.asarray(gt.data)
    causal_order(x).block_until_ready()  # compile
    t0 = time.perf_counter()
    o_full = causal_order(x)
    o_full.block_until_ready()
    t_full = time.perf_counter() - t0
    causal_order_compact(x)  # compile
    t0 = time.perf_counter()
    o_staged = causal_order_compact(x)
    t_staged = time.perf_counter() - t0
    same = bool(np.array_equal(np.asarray(o_full), np.asarray(o_staged)))
    emit(f"- full {t_full:.2f}s vs staged {t_staged:.2f}s "
         f"({t_full/t_staged:.2f}x), identical order: {same}")
    record("C", "C1-wallclock", "", f"{t_full:.2f}s", f"{t_staged:.2f}s",
           "CONFIRMED" if same and t_staged < t_full else "REFUTED")

    emit("")
    emit("**C2 — fuse standardization into the moment pass** (correlation "
         "from the raw-X matmul + affine fold: C = D(Craw/m - mu mu^T)D)")
    emit("- Hypothesis: 3 X-slab passes/step -> 2; memory x2/3.")
    c2_t, _, _, _ = _lingam_terms(1_000_000, 2048, staged=True, passes=2)
    emit(f"- after: {fmt(c2_t)}")
    record("C", "C2-fused-standardize", "one slab pass saved", fmt(c1_t),
           fmt(c2_t), "CONFIRMED (analytic)")

    emit("")
    emit("**C3 — bf16 X streaming (fp32 accumulation in the kernel)**")
    emit("- Hypothesis: slab bytes halve; compute unchanged (kernel "
         "accumulates fp32 — same moments to ~1e-3, which does not change "
         "the argmax on tested sims). Memory x1/2.")
    c3_t, c3_fl, c3_by, _ = _lingam_terms(
        1_000_000, 2048, staged=True, passes=2, elem_bytes=2
    )
    emit(f"- after: {fmt(c3_t)}")
    gain = base_t["bound_s"] / c3_t["bound_s"]
    emit(f"- cumulative: {base_t['bound_s']:.2f}s -> {c3_t['bound_s']:.2f}s "
         f"({gain:.2f}x); dominant: {c3_t['dominant']} — remaining gap to "
         "peak is the VPU transcendental ceiling (logcosh/exp are not MXU "
         "work; documented in EXPERIMENTS.md).")
    record("C", "C3-bf16-stream", "memory halves", fmt(c2_t), fmt(c3_t),
           "CONFIRMED (analytic)")
    return base_t, c3_t


# ======================================================================
# Cell D (bonus): olmoe train_4k
# ======================================================================
def cell_d():
    emit("")
    emit("## Cell D (bonus) — olmoe-1b-7b / train_4k (EP-bound MoE)")
    base_t, _, base_coll = lm_terms("olmoe-1b-7b", "train_4k")
    emit(f"- baseline: {fmt(base_t)}; collective parts: "
         f"{ {k: f'{v/1e9:.1f}GB' for k, v in base_coll.items()} }")
    emit("**D1 — bf16 gradient all-reduce** (`grad_dtype=bfloat16` in "
         "train_step; fp32 master accumulate in AdamW)")
    d1_t, _, d1_coll = lm_terms("olmoe-1b-7b", "train_4k", grad_bytes=2)
    emit(f"- after: {fmt(d1_t)} — dp_gradreduce "
         f"{base_coll['dp_gradreduce']/1e9:.2f}GB -> "
         f"{d1_coll['dp_gradreduce']/1e9:.2f}GB")
    emit("- verdict: CONFIRMED but NOT the bottleneck — EP all-to-all "
         f"({base_coll['ep_alltoall']/1e9:.1f}GB/dev) dominates; top-8 "
         "routing moves each token 8x both ways. The structural fix "
         "(future work): hierarchical all-to-all within-pod + "
         "expert-weight gathering when token-bytes >> expert-bytes.")
    emit("**D2 — einsum vs scatter dispatch (FLOPs sanity):**")
    d2_t, d2_c, _ = lm_terms("olmoe-1b-7b", "train_4k", moe_impl="einsum")
    emit(f"- einsum dispatch: {fmt(d2_t)} (compute "
         f"{d2_t['compute_s']/base_t['compute_s']:.1f}x baseline) — the "
         "GShard one-hot einsum inflates FLOPs; scatter dispatch (our "
         "default) avoids it. CONFIRMED scatter as default.")
    record("D", "D1-bf16-grads", "", fmt(base_t), fmt(d1_t), "CONFIRMED")
    record("D", "D2-einsum-moe", "", fmt(base_t), fmt(d2_t),
           "scatter confirmed as default")
    return base_t, d1_t


def main():
    t0 = time.time()
    cell_a()
    cell_b()
    cell_c()
    cell_d()
    cell_e()
    emit("")
    emit(f"_(generated in {time.time()-t0:.0f}s)_")
    with open("experiments/hillclimb.md", "w") as f:
        f.write("\n".join(LINES))
    with open("experiments/hillclimb.json", "w") as f:
        json.dump(RESULTS, f, indent=1)


if __name__ == "__main__":
    main()


# ======================================================================
# Cell E: nemotron-4-340b train_4k — push the best cell toward roofline
# ======================================================================
def cell_e():
    emit("")
    emit("## Cell E — nemotron-4-340b / train_4k (highest-fraction cell; "
         "push toward roofline)")
    base_t, base_c, _ = lm_terms("nemotron-4-340b", "train_4k")
    emit(f"- baseline (full remat): {fmt(base_t)}")
    emit("**E1 — selective remat: save matmul outputs** "
         "(`remat_policy=dots`, jax dots_with_no_batch_dims_saveable)")
    emit("- Hypothesis: full remat replays the entire fwd (+1x of fwd "
         "FLOPs = +33% of the train step); matmuls are ~95% of layer "
         "FLOPs, so saving dot outputs and replaying only elementwise/"
         "norm work cuts the replay to ~5%: compute x(3.05/4) ~= 0.76x; "
         "activation bytes rise (0.6 -> 0.8 coeff) but memory is not the "
         "bound. Predicted: 86.9s -> ~66s, fraction 49% -> ~64%.")
    e1_t, e1_c, _ = lm_terms(
        "nemotron-4-340b", "train_4k",
        cfg_overrides={"remat_policy": "dots"},
    )
    emit(f"- after: {fmt(e1_t)}")
    gain = base_t["bound_s"] / e1_t["bound_s"]
    v = "CONFIRMED" if 0.70 < e1_t["bound_s"] / base_t["bound_s"] < 0.82 \
        else "PARTIAL"
    emit(f"- verdict: {v} ({gain:.2f}x)")
    record("E", "E1-dots-remat", "full-remat replay is 25% of step",
           fmt(base_t), fmt(e1_t), v)

    emit("**E1 HLO evidence (lower+compile with the dots policy):**")
    ev = hlo_evidence("nemotron-4-340b", "train_4k",
                      cfg_overrides={"remat_policy": "dots"})
    emit(f"- compile {ev['compile_s']}s OK; collectives "
         f"{ {k: f'{v/1e9:.2f}GB' for k, v in ev['hlo_collectives'].items()} }")
    record("E", "E1-hlo", "", "", "", "", ev)
    return base_t, e1_t



